"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16; parallel attention + mamba heads per layer.
[arXiv:2411.13676; hf]

Per Hymba: layers 0, 15 and 31 use global attention, the rest sliding-window;
the SSM path is always global (bounded state) => long_500k applicable.
"""
from repro.configs.base import ModelConfig, SSMConfig

_W = 1_024

# 32-entry pattern: global at 0, 15, 31.
_PATTERN = tuple(0 if i in (0, 15, 31) else _W for i in range(32))

CONFIG = ModelConfig(
    arch="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1_600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5_504,
    vocab=32_001,
    act="swiglu",
    attn_pattern=_PATTERN,
    local_window=_W,
    parallel_ssm=True,
    ssm=SSMConfig(state_dim=16, d_inner_mult=2, chunk=128),
    supports_long_context=True,
    remat="dots",
)

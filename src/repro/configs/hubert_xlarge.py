"""hubert-xlarge [audio] — 48L d_model=1280 16H d_ff=5120 vocab=504.
Encoder-only (same backbone as wav2vec2). [arXiv:2106.07447; unverified]

The conv waveform frontend is a STUB — ``input_specs()`` supplies precomputed
frame embeddings (B, T, d_model). vocab=504 is the masked-prediction codebook.
Encoder-only: decode shape cells are skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1_280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5_120,
    vocab=504,
    act="gelu",
    encoder_only=True,
    embedding_inputs=True,
    remat="dots",
)

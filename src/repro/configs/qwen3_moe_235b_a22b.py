"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]

128 experts over the 16-way model axis: 8 experts/device ("ep" mode).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4_096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1_536,
    vocab=151_936,
    act="swiglu",
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1_536,
                  capacity_factor=1.25, parallel_mode="ep"),
    optimizer_dtype="bfloat16",
    remat="full",
)

"""Config system: architecture, shape-cell, mesh and run configuration.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (full-scale, exact paper numbers) built on :class:`ModelConfig`.
``ModelConfig.reduced()`` derives the CPU-smoke-test variant of the same
family (small widths / few layers / few experts / tiny vocab).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Shape cells (assigned input-shape set for the LM family)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeCell:
    """One (workload kind, seq_len, global_batch) cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeCell("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeCell("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeCell("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeCell("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[ShapeCell, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # "ep": experts sharded over model axis; "tp": expert d_ff sharded.
    parallel_mode: str = "ep"


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    d_inner_mult: int = 2
    conv_width: int = 4
    chunk: int = 128  # chunk length for chunkwise-parallel scans
    # compute projections/gates inside the chunk scan (memory-optimised;
    # baseline materialises (B,T,di,N) inputs for the whole sequence)
    chunk_local: bool = False


@dataclass(frozen=True)
class ModelConfig:
    # identity
    arch: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    # transformer backbone
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # activations / variants
    act: str = "swiglu"  # swiglu | relu2 | gelu
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # attention pattern: window size per layer; 0 == global. Specified via a
    # repeating pattern applied cyclically over layers.
    attn_pattern: Tuple[int, ...] = (0,)
    local_window: int = 1_024
    rope_theta_global: Optional[float] = None  # gemma3: different theta on globals
    # encoder-only (no causal mask, no decode step)
    encoder_only: bool = False
    # cross-attention (VLM): one cross-attn layer after every `cross_attn_every`
    # self-attn layers; 0 == disabled. n_layers counts self-attn layers.
    cross_attn_every: int = 0
    n_vision_tokens: int = 0
    # MoE / SSM / hybrid
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # xLSTM: every `slstm_every`-th block is an sLSTM block (0 == none)
    slstm_every: int = 0
    # hybrid (hymba): attention and SSM run in parallel in each layer
    parallel_ssm: bool = False
    # modality frontend stub (audio/vlm): inputs arrive as embeddings
    embedding_inputs: bool = False
    # long-context capability (sub-quadratic path exists)
    supports_long_context: bool = False
    # numerics / memory policy
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    optimizer_dtype: str = "float32"  # bf16 for the largest archs
    remat: str = "full"  # full | dots | none
    # gradient-accumulation microbatches for train_4k (global_batch divides)
    train_microbatches: int = 8
    # two-level remat: scan over groups of layers, remat inside groups
    remat_groups: Optional[int] = None
    # scan segmentation for heterogeneous stacks (set automatically)
    logical_axis_rules: Tuple[Tuple[str, Optional[str]], ...] = ()

    # -- derived ------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def window_for_layer(self, i: int) -> int:
        return self.attn_pattern[i % len(self.attn_pattern)]

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks), for roofline math."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        p = V * D * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family != "ssm":  # xLSTM blocks carry no attention
            per_layer += (D * self.q_dim + 2 * D * self.kv_dim
                          + self.q_dim * D)
        # norms
        per_layer += 2 * D
        if self.moe is not None:
            e, fe = self.moe.num_experts, self.moe.d_ff_expert
            n_mats = 3 if self.act == "swiglu" else 2
            per_layer += D * e + e * n_mats * D * fe
        elif self.parallel_ssm and self.ssm is not None:
            di = self.ssm.d_inner_mult * D
            per_layer += D * 2 * di + di * D + di * (2 * self.ssm.state_dim + 2)
            n_mats = 3 if self.act == "swiglu" else 2
            per_layer += n_mats * D * F
        elif self.family == "ssm":
            # xLSTM mLSTM block: Wq,Wk,Wv,Wo,Wog (DxD each) + scalar gate projs
            per_layer += 5 * D * D + 2 * D * self.n_heads
        else:
            n_mats = 3 if self.act == "swiglu" else 2
            per_layer += n_mats * D * F
        if self.cross_attn_every:
            n_cross = L // self.cross_attn_every
            cross = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D + 2 * D
            p += n_cross * cross
        return p + L * per_layer

    def n_active_params(self) -> int:
        """Active (per-token) parameters — differs from n_params for MoE."""
        if self.moe is None:
            return self.n_params()
        full = self.n_params()
        e, k, fe = self.moe.num_experts, self.moe.top_k, self.moe.d_ff_expert
        n_mats = 3 if self.act == "swiglu" else 2
        inactive = self.n_layers * (e - k) * n_mats * self.d_model * fe
        return full - inactive

    def shape_cells(self) -> Tuple[ShapeCell, ...]:
        """The assigned shape cells applicable to this architecture."""
        cells = [TRAIN_4K, PREFILL_32K]
        if not self.encoder_only:
            cells.append(DECODE_32K)
            if self.supports_long_context:
                cells.append(LONG_500K)
        return tuple(cells)

    def skipped_cells(self) -> Tuple[Tuple[str, str], ...]:
        out = []
        if self.encoder_only:
            out.append(("decode_32k", "encoder-only architecture: no decode step"))
            out.append(("long_500k", "encoder-only architecture: no decode step"))
        elif not self.supports_long_context:
            out.append(
                ("long_500k", "pure full-attention architecture: 500k dense KV "
                              "cache / quadratic attention; no sub-quadratic path")
            )
        return tuple(out)

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            n_vision_tokens=16 if self.cross_attn_every else 0,
            remat="none",
        )
        if self.cross_attn_every:
            kw["cross_attn_every"] = 2
            kw["n_layers"] = 4
        if self.slstm_every:
            kw["slstm_every"] = 2
        if self.moe is not None:
            kw["moe"] = MoEConfig(
                num_experts=4, top_k=min(self.moe.top_k, 2), d_ff_expert=64,
                capacity_factor=2.0, parallel_mode=self.moe.parallel_mode)
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(state_dim=8, chunk=16)
        if len(self.attn_pattern) > 1:
            kw["attn_pattern"] = self.attn_pattern[: 2]
            kw["local_window"] = 8
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ASSIGNED_ARCHS: Tuple[str, ...] = (
    "grok-1-314b",
    "qwen3-moe-235b-a22b",
    "xlstm-1.3b",
    "llama-3.2-vision-11b",
    "hubert-xlarge",
    "llama3.2-3b",
    "internlm2-20b",
    "gemma3-1b",
    "nemotron-4-340b",
    "hymba-1.5b",
)


def get_config(arch: str) -> ModelConfig:
    """Load the full-scale config for an assigned architecture id."""
    import importlib

    mod_name = "repro.configs." + arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(mod_name)
    cfg = mod.CONFIG
    assert cfg.arch == arch, (cfg.arch, arch)
    return cfg


def all_cells() -> Sequence[Tuple[str, ShapeCell]]:
    """Every runnable (arch, shape) dry-run cell."""
    out = []
    for a in ASSIGNED_ARCHS:
        cfg = get_config(a)
        for cell in cfg.shape_cells():
            out.append((a, cell))
    return out

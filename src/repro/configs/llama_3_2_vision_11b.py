"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; cross-attention image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Backbone only: the vision frontend is a STUB — ``input_specs()`` supplies
precomputed patch embeddings (B, n_vision_tokens, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4_096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab=128_256,
    act="swiglu",
    cross_attn_every=5,          # 40 self-attn layers -> 8 cross-attn layers
    n_vision_tokens=1_600,
    rope_theta=500_000.0,
    remat="full",
)

"""nemotron-4-340b [dense] — 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000; squared-ReLU MLP. [arXiv:2402.16819; unverified]

340B dense: FSDP x TP, full remat, bf16 optimizer states are mandatory to fit
256 x 16 GiB chips.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18_432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73_728,
    vocab=256_000,
    act="relu2",
    optimizer_dtype="bfloat16",
    remat="full",
    remat_groups=12,  # 96 = 12 groups x 8 layers: two-level remat
)

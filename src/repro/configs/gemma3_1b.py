"""gemma3-1b [dense] — 26L d_model=1152 4H (MQA kv=1) d_ff=6912 vocab=262144.
5:1 local(sliding-window):global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

Sliding-window local layers give a sub-quadratic path; the single global
layer per period uses a sequence-sharded KV cache at long_500k.
attn_pattern: 5 windowed layers then 1 global, cyclically.
"""
from repro.configs.base import ModelConfig

_W = 1_024  # sliding window

CONFIG = ModelConfig(
    arch="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1_152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6_912,
    vocab=262_144,
    act="geglu",
    attn_pattern=(_W, _W, _W, _W, _W, 0),
    local_window=_W,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    tie_embeddings=True,
    supports_long_context=True,
    remat="dots",
)

"""xlstm-1.3b [ssm] — 48L d_model=2048 4H d_ff=0 vocab=50304.
sLSTM + mLSTM blocks (7:1 ratio — one sLSTM block every 8th layer).
[arXiv:2405.04517; unverified]

Recurrent matrix-memory state => O(1) decode; long_500k applicable.
mLSTM runs in chunkwise-parallel form (sub-quadratic training/prefill).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2_048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=0,                      # mLSTM blocks have no separate FFN
    vocab=50_304,
    slstm_every=8,
    ssm=SSMConfig(state_dim=512, chunk=128),
    supports_long_context=True,
    remat="full",
)

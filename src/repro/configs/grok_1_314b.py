"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2. [hf:xai-org/grok-1; unverified]

8 experts < 16-way model axis: expert d_ff is tensor-parallel ("tp" mode).
314B params / ~86B active.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6_144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32_768,
    vocab=131_072,
    act="swiglu",
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32_768,
                  capacity_factor=1.25, parallel_mode="tp"),
    optimizer_dtype="bfloat16",  # 314B: fp32 m/v would not fit 256 chips
    remat="full",
)

"""Checkpointing: atomic, async-capable, reshard-on-restore.

Layout:  <dir>/step_<N>/   one .npy per pytree leaf + manifest.json
         <dir>/LATEST      (atomic pointer file, written last)

Fault-tolerance contract:
- writes go to step_<N>.tmp then a single atomic rename; a crash mid-save
  never corrupts the previous checkpoint;
- `restore` can place arrays onto a DIFFERENT mesh/sharding than the save
  used (elastic restarts after losing nodes);
- `AsyncCheckpointer` snapshots device arrays to host and writes in a
  background thread so the train loop is blocked only for the device->host
  copy (checkpoint/compute overlap).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = leaf
    return flat


def save(directory, step: int, tree, *, keep: int = 3) -> Path:
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f"step_{step}.tmp"
    final = d / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}, "time": time.time()}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fn = key.replace("/", "__") + ".npy"
        logical = str(arr.dtype)
        if arr.dtype.kind == "V" or logical == "bfloat16":
            # non-native dtypes (bfloat16): store the raw bit pattern
            np.save(tmp / fn, arr.view(np.uint16))
        else:
            np.save(tmp / fn, arr)
        manifest["leaves"][key] = {"file": fn, "shape": list(arr.shape),
                                   "dtype": logical}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic
    latest = d / "LATEST"
    tmp_l = d / "LATEST.tmp"
    tmp_l.write_text(str(step))
    os.replace(tmp_l, latest)                   # atomic pointer
    _gc(d, keep)
    return final


def _gc(d: Path, keep: int):
    steps = sorted((int(p.name.split("_")[1]) for p in d.glob("step_*")
                    if p.name.split("_")[1].isdigit()))
    for s in steps[:-keep]:
        shutil.rmtree(d / f"step_{s}", ignore_errors=True)


def latest_step(directory) -> Optional[int]:
    p = Path(directory) / "LATEST"
    if not p.exists():
        return None
    try:
        step = int(p.read_text().strip())
    except ValueError:
        return None
    return step if (Path(directory) / f"step_{step}").exists() else None


def restore(directory, step: int, target_tree, shardings=None):
    """Restore into the structure of target_tree (SDS or arrays); if
    `shardings` (matching pytree) is given, device_put with those shardings —
    this is the elastic-remesh path."""
    d = Path(directory) / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat_t = _flatten(target_tree)
    flat_s = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key, struct in flat_t.items():
        info = manifest["leaves"].get(key)
        if info is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(d / info["file"])
        if info["dtype"] == "bfloat16" and arr.dtype == np.uint16:
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if tuple(arr.shape) != tuple(struct.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {struct.shape}")
        if key in flat_s:
            out[key] = jax.device_put(arr, flat_s[key])
        else:
            out[key] = jax.device_put(arr.astype(struct.dtype))
    # unflatten back into target structure
    leaves, treedef = jax.tree_util.tree_flatten(target_tree)
    keys = list(_flatten(target_tree).keys())
    return jax.tree_util.tree_unflatten(treedef,
                                        [out[k] for k in keys])


class AsyncCheckpointer:
    """Snapshot-to-host then background write; at most one pending save."""

    def __init__(self, directory, keep: int = 3):
        self.dir = Path(directory)
        self.keep = keep
        self._pending: Optional[threading.Thread] = None
        self.saved_steps = []

    def save(self, step: int, tree):
        self.wait()
        host = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)),
                                      tree)

        def _write():
            save(self.dir, step, host, keep=self.keep)
            self.saved_steps.append(step)

        self._pending = threading.Thread(target=_write, daemon=True)
        self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

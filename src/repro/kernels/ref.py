"""Pure-jnp oracles for the rule-match kernels."""
from __future__ import annotations

import jax.numpy as jnp


def rule_match_ref(queries, mins, maxs, weights):
    """Dense interval-stabbing rule match.

    queries: (B, C) int32; mins/maxs: (R, C) int32; weights: (R,) int32
    (padding rules carry weight < 0 and never-matching intervals).
    Returns (best_weight (B,), best_idx (B,)) — highest weight among matching
    rules, lowest index tie-break; (-1, -1) when nothing matches.
    """
    q = queries[:, None, :]                     # (B, 1, C)
    ok = (q >= mins[None]) & (q <= maxs[None])  # (B, R, C)
    matched = jnp.all(ok, axis=-1)              # (B, R)
    score = jnp.where(matched, weights[None, :], -1)
    best = jnp.max(score, axis=1)
    idx = jnp.argmax(score, axis=1).astype(jnp.int32)  # first max == lowest idx
    idx = jnp.where(best < 0, -1, idx)
    return best.astype(jnp.int32), idx

"""Jitted wrappers around the rule-match kernel: padding, layout transposes,
engine-lane splitting, and the partitioned (NFA-prefix-pruning analog) mode.

``match_rules`` is the public op. ``partitioned=True`` buckets queries by the
partition criterion (airport) — the dense analog of the NFA's first-level
fanout — and matches each query only against its partition's rule block plus
the wildcard block, cutting compute by ~n_partitions/skew.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as ref_mod
from repro.kernels.rule_match import rule_match_pallas


def _pad_to(x, m, axis, value):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


class DeviceRuleTable(NamedTuple):
    """Device-resident compiled rule table (criterion-major layouts)."""
    mins_t: jax.Array     # (C, Rp) int32
    maxs_t: jax.Array     # (C, Rp)
    weights: jax.Array    # (1, Rp) (-1 padding)
    decisions: jax.Array  # (Rp,)
    rule_ids: jax.Array   # (Rp,)
    n_rules: int
    # partitioned-mode blocks (optional)
    part_mins: Optional[jax.Array] = None   # (NP, Pmax, C)
    part_maxs: Optional[jax.Array] = None
    part_w: Optional[jax.Array] = None      # (NP, Pmax)
    part_rows: Optional[jax.Array] = None   # (NP, Pmax) row in dense table
    partition_col: int = 0


def device_table(table, tile_r: int = 512, partitioned: bool = False,
                 max_block: Optional[int] = None) -> DeviceRuleTable:
    """Upload a CompiledRuleTable; optionally build partition blocks."""
    mins = jnp.asarray(table.mins, jnp.int32)
    maxs = jnp.asarray(table.maxs, jnp.int32)
    w = jnp.asarray(table.weights, jnp.int32)
    mins_t = _pad_to(mins.T, tile_r, 1, 1)
    maxs_t = _pad_to(maxs.T, tile_r, 1, 0)      # min>max: never matches
    wp = _pad_to(w[None, :], tile_r, 1, -1)
    dec = _pad_to(jnp.asarray(table.decisions, jnp.int32), tile_r, 0, 0)
    rid = _pad_to(jnp.asarray(table.rule_ids, jnp.int32), tile_r, 0, -1)

    kw = {}
    if partitioned:
        NP = table.n_partitions
        counts = np.diff(table.part_offsets)
        wc = table.wildcard_rows
        pmax = int(counts.max() if len(counts) else 0) + len(wc)
        if max_block:
            pmax = min(pmax, max_block)
        pmax = max(pmax, 1)
        rows = np.full((NP, pmax), -1, np.int64)
        for p in range(NP):
            own = table.part_order[table.part_offsets[p]:
                                   table.part_offsets[p + 1]]
            blk = np.concatenate([own, wc])[:pmax]
            rows[p, :len(blk)] = blk
        valid = rows >= 0
        safe = np.where(valid, rows, 0)
        pm = table.mins[safe]
        px = table.maxs[safe]
        pw = np.where(valid, table.weights[safe], -1)
        pm = np.where(valid[..., None], pm, 1)
        px = np.where(valid[..., None], px, 0)
        kw = dict(part_mins=jnp.asarray(pm, jnp.int32),
                  part_maxs=jnp.asarray(px, jnp.int32),
                  part_w=jnp.asarray(pw, jnp.int32),
                  part_rows=jnp.asarray(safe, jnp.int32),
                  partition_col=table.partition_col)

    return DeviceRuleTable(mins_t=mins_t, maxs_t=maxs_t, weights=wp,
                           decisions=dec, rule_ids=rid,
                           n_rules=table.n_rules, **kw)


@functools.partial(jax.jit, static_argnames=("tile_b", "tile_r", "backend",
                                             "n_engines", "interpret"))
def match_rules(queries, dt: DeviceRuleTable, *, tile_b: int = 256,
                tile_r: int = 512, backend: str = "pallas",
                n_engines: int = 1, interpret: bool = True):
    """queries: (B, C) int32. Returns (decision, weight, rule_id) (B,) each.

    n_engines splits the batch into parallel kernel lanes (the paper's
    'NFA evaluation engines per kernel' axis) via vmap.
    """
    B, C = queries.shape
    qp = _pad_to(queries, tile_b * n_engines, 0, 0)
    Bp = qp.shape[0]

    if backend == "ref":
        w, idx = ref_mod.rule_match_ref(qp, dt.mins_t.T, dt.maxs_t.T,
                                        dt.weights[0])
    else:
        qt = qp.T  # (C, Bp)
        if n_engines > 1:
            lanes = qt.reshape(C, n_engines, Bp // n_engines).swapaxes(0, 1)
            fn = functools.partial(rule_match_pallas, tile_b=tile_b,
                                   tile_r=tile_r, interpret=interpret)
            bw, bi = jax.vmap(lambda q: fn(q, dt.mins_t, dt.maxs_t,
                                           dt.weights))(lanes)
            w = bw.reshape(Bp)
            idx = bi.reshape(Bp)
        else:
            bw, bi = rule_match_pallas(qt, dt.mins_t, dt.maxs_t, dt.weights,
                                       tile_b=tile_b, tile_r=tile_r,
                                       interpret=interpret)
            w, idx = bw[0], bi[0]

    w, idx = w[:B], idx[:B]
    safe = jnp.maximum(idx, 0)
    dec = jnp.where(idx >= 0, dt.decisions[safe], jnp.int32(-1))
    rid = jnp.where(idx >= 0, dt.rule_ids[safe], jnp.int32(-1))
    return dec, w.astype(jnp.int32), rid


@jax.jit
def match_rules_partitioned(queries, dt: DeviceRuleTable):
    """Partition-pruned matching (NFA first-level fanout analog).

    Each query gathers its airport-partition rule block (padded, wildcard
    rules appended) and matches only against it: per-query work drops from
    R to Pmax. queries: (B, C) int32.
    """
    pcol = dt.partition_col
    part = queries[:, pcol]                                # (B,) codes
    NP = dt.part_mins.shape[0]
    pid = jnp.clip(part, 0, NP - 1)
    mn = dt.part_mins[pid]                                 # (B, Pmax, C)
    mx = dt.part_maxs[pid]
    w = dt.part_w[pid]                                     # (B, Pmax)
    rows = dt.part_rows[pid]
    ok = jnp.all((queries[:, None, :] >= mn) & (queries[:, None, :] <= mx),
                 axis=-1)                                  # (B, Pmax)
    score = jnp.where(ok, w, -1)
    best = jnp.max(score, axis=1)
    # lowest dense-table row among ties (matches dense-engine tie-break)
    cand_rows = jnp.where(score == best[:, None], rows, jnp.int32(2 ** 30))
    row = jnp.min(cand_rows, axis=1)
    good = best >= 0
    safe = jnp.where(good, row, 0)
    dec = jnp.where(good, dt.decisions[safe], jnp.int32(-1))
    rid = jnp.where(good, dt.rule_ids[safe], jnp.int32(-1))
    return dec, jnp.where(good, best, -1).astype(jnp.int32), rid

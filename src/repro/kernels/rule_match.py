"""Pallas TPU kernel: dense interval-stabbing rule matcher (ERBIUM-on-TPU).

TPU adaptation of the NFA evaluation engine: instead of pointer-chasing a
transition graph (FPGA spatial pipeline), the rule set is a dense interval
table evaluated tile-by-tile in VMEM with a running best-(weight, index)
reduction. Layouts are criterion-major — queries (C, B), rules (C, R) — so
the minor (lane) dimension is 128-aligned for the VPU; the conjunction over
criteria is an unrolled loop of (TB, TR) compare-AND steps, which is the
MXU/VPU-friendly reformulation of the NFA's per-level transitions.

Grid: (B/TB, R/TR) with the rule dim innermost; the output block for a batch
tile is revisited across rule tiles and carries the running argmax (standard
TPU revisiting-output accumulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def _kernel(q_ref, mn_ref, mx_ref, w_ref, bw_ref, bi_ref, *, n_crit: int,
            tile_r: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        bw_ref[...] = jnp.full_like(bw_ref, -1)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    tb = q_ref.shape[1]
    tr = mn_ref.shape[1]
    acc = jnp.ones((tb, tr), jnp.bool_)
    for c in range(n_crit):  # unrolled conjunction over criteria
        qc = q_ref[c, :]                      # (TB,)
        mn, mx = mn_ref[c, :], mx_ref[c, :]   # (TR,)
        acc &= (qc[:, None] >= mn[None, :]) & (qc[:, None] <= mx[None, :])

    w = w_ref[0, :]                           # (TR,)
    score = jnp.where(acc, w[None, :], jnp.int32(-1))  # (TB, TR)
    best = jnp.max(score, axis=1)             # (TB,)
    ridx = jax.lax.broadcasted_iota(jnp.int32, (tb, tr), 1)
    cand = jnp.where(score == best[:, None], ridx, jnp.int32(tr))
    arg = jnp.min(cand, axis=1) + j * tile_r  # global rule index, lowest-tie

    prev_w = bw_ref[0, :]
    better = best > prev_w                    # strict: earlier tile wins ties
    bw_ref[0, :] = jnp.where(better, best, prev_w)
    bi_ref[0, :] = jnp.where(better & (best >= 0), arg, bi_ref[0, :])


def rule_match_pallas(queries_t, mins_t, maxs_t, weights,
                      *, tile_b: int = 256, tile_r: int = 512,
                      interpret: bool = True):
    """queries_t: (C, B) int32; mins_t/maxs_t: (C, R); weights: (1, R).

    B % tile_b == 0 and R % tile_r == 0 (ops.py pads).
    Returns (best_w (1, B), best_i (1, B)).
    """
    C, B = queries_t.shape
    R = mins_t.shape[1]
    assert B % tile_b == 0 and R % tile_r == 0, (B, R, tile_b, tile_r)
    grid = (B // tile_b, R // tile_r)

    kern = functools.partial(_kernel, n_crit=C, tile_r=tile_r)
    out_shape = [jax.ShapeDtypeStruct((1, B), jnp.int32),
                 jax.ShapeDtypeStruct((1, B), jnp.int32)]
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((C, tile_b), lambda i, j: (0, i)),
            pl.BlockSpec((C, tile_r), lambda i, j: (0, j)),
            pl.BlockSpec((C, tile_r), lambda i, j: (0, j)),
            pl.BlockSpec((1, tile_r), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile_b), lambda i, j: (0, i)),
            pl.BlockSpec((1, tile_b), lambda i, j: (0, i)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(queries_t, mins_t, maxs_t, weights)

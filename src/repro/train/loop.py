"""Training loop: microbatched train step + prefetching data pipeline +
async checkpointing + failure handling (elastic restart) + straggler policy.

Single-process on this container, but every distributed hook is the real
code path: the loop consumes per-shard data, restores onto remapped meshes,
and commits steps through the straggler policy.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs.base import ModelConfig
from repro.data.pipeline import Prefetcher, ShardSpec
from repro.ft.failures import FailureInjector, StragglerPolicy
from repro.launch.steps import build_train_step, make_ctx
from repro.models.registry import Model, build_model
from repro.sharding.specs import ShardCtx
from repro.train.optimizer import AdamW


@dataclass
class TrainConfig:
    steps: int = 100
    batch: int = 8
    seq_len: int = 128
    lr: float = 3e-4
    warmup: int = 20
    microbatches: int = 1
    schedule_steps: Optional[int] = None  # LR schedule horizon (default steps)
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    seed: int = 0
    log_every: int = 10


@dataclass
class TrainResult:
    losses: List[float]
    steps_done: int
    restarts: int
    step_times: List[float]


def fit(cfg: ModelConfig, tc: TrainConfig, *, ctx: Optional[ShardCtx] = None,
        injector: Optional[FailureInjector] = None,
        log: Callable[[str], None] = print) -> TrainResult:
    model = build_model(cfg, ctx)
    opt = AdamW(lr=tc.lr, warmup=tc.warmup,
                total_steps=tc.schedule_steps or tc.steps,
                state_dtype=jnp.bfloat16
                if cfg.optimizer_dtype == "bfloat16" else jnp.float32)
    step_fn = build_train_step(model, ctx, opt, tc.microbatches) \
        if ctx else _local_step(model, opt, tc.microbatches)
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    params = model.init(jax.random.PRNGKey(tc.seed))
    opt_state = opt.init(params)
    start = 0
    ckpt = store.AsyncCheckpointer(tc.ckpt_dir, keep=tc.keep) \
        if tc.ckpt_dir else None
    if tc.ckpt_dir:
        last = store.latest_step(tc.ckpt_dir)
        if last is not None:
            tree = {"params": params, "opt": opt_state}
            restored = store.restore(tc.ckpt_dir, last, tree)
            params, opt_state = restored["params"], restored["opt"]
            start = last
            log(f"[train] resumed from step {last}")

    pf = Prefetcher(cfg, tc.batch, tc.seq_len, seed=tc.seed,
                    start_step=start)
    straggler = StragglerPolicy()
    losses, times = [], []
    restarts = 0
    step = start
    try:
        while step < tc.steps:
            if injector is not None and injector.check(step):
                # simulated node failure: drop state, restore from ckpt
                injector.schedule.pop(step, None)  # fires once
                restarts += 1
                log(f"[train] injected failure at step {step}; restarting")
                if ckpt:
                    ckpt.wait()
                last = store.latest_step(tc.ckpt_dir) if tc.ckpt_dir else None
                if last is None:
                    params = model.init(jax.random.PRNGKey(tc.seed))
                    opt_state = opt.init(params)
                    step = 0
                else:
                    tree = {"params": params, "opt": opt_state}
                    restored = store.restore(tc.ckpt_dir, last, tree)
                    params, opt_state = restored["params"], restored["opt"]
                    step = last
                pf.close()
                pf = Prefetcher(cfg, tc.batch, tc.seq_len, seed=tc.seed,
                                start_step=step)
                continue

            t0 = time.perf_counter()
            got_step, batch = pf.next()
            assert got_step == step, (got_step, step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = jitted(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            straggler.observe(dt)
            losses.append(loss)
            times.append(dt)
            step += 1
            if step % tc.log_every == 0:
                log(f"[train] step={step} loss={loss:.4f} "
                    f"dt={dt*1e3:.1f}ms")
            if ckpt and step % tc.ckpt_every == 0:
                ckpt.save(step, {"params": params, "opt": opt_state})
        if ckpt:
            ckpt.save(tc.steps, {"params": params, "opt": opt_state})
            ckpt.wait()
    finally:
        pf.close()
    return TrainResult(losses=losses, steps_done=step, restarts=restarts,
                       step_times=times)


def _local_step(model: Model, opt: AdamW, n_mb: int):
    def step(params, opt_state, batch):
        def loss_fn(p, mb):
            return model.loss(p, mb)

        if n_mb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)
        else:
            mbs = jax.tree_util.tree_map(
                lambda t: t.reshape((n_mb, t.shape[0] // n_mb)
                                    + t.shape[1:]), batch)

            def body(acc, mb):
                g_acc, l_acc = acc
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gs, ls), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / n_mb, gs)
            loss = ls / n_mb
        new_p, new_s, gnorm = opt.update(grads, opt_state, params)
        return new_p, new_s, {"loss": loss, "grad_norm": gnorm}

    return step

"""AdamW with configurable state dtype (bf16 states for the 314B/340B archs),
global-norm clipping and cosine schedule. States inherit parameter sharding
(FSDP => ZeRO-3 automatically under GSPMD).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: Any = jnp.float32
    warmup: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1

    def schedule(self, step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(self.warmup, 1), 1.0)
        prog = jnp.clip((s - self.warmup) /
                        jnp.maximum(self.total_steps - self.warmup, 1),
                        0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(np.pi * prog))
        frac = self.min_lr_frac + (1 - self.min_lr_frac) * cos
        return self.lr * warm * frac

    def init(self, params) -> AdamWState:
        z = lambda p: jnp.zeros(p.shape, self.state_dtype)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree_util.tree_map(z, params),
                          nu=jax.tree_util.tree_map(z, params))

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState, jax.Array]:
        """Returns (new_params, new_state, grad_norm)."""
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree_util.tree_leaves(g32)))
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            g32 = jax.tree_util.tree_map(lambda g: g * scale, g32)
        step = state.step + 1
        lr = self.schedule(step)
        c1 = 1 - self.b1 ** step.astype(jnp.float32)
        c2 = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
            m_new = self.b1 * m32 + (1 - self.b1) * g
            v_new = self.b2 * v32 + (1 - self.b2) * jnp.square(g)
            mh, vh = m_new / c1, v_new / c2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * delta
            return (p_new.astype(p.dtype), m_new.astype(self.state_dtype),
                    v_new.astype(self.state_dtype))

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(g32)
        flat_m = jax.tree_util.tree_leaves(state.mu)
        flat_v = jax.tree_util.tree_leaves(state.nu)
        res = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree_util.tree_unflatten(tdef, [r[0] for r in res])
        new_m = jax.tree_util.tree_unflatten(tdef, [r[1] for r in res])
        new_v = jax.tree_util.tree_unflatten(tdef, [r[2] for r in res])
        return new_p, AdamWState(step=step, mu=new_m, nu=new_v), gnorm

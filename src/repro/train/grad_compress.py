"""Int8 gradient compression with error feedback (distributed-optimization
trick for cross-pod / DCN all-reduce).

Per-tensor symmetric quantisation: g ~ scale * q, q in int8. The residual
(g - scale*q) is carried to the next step (error feedback), which keeps SGD
convergence (Karimireddy et al., 2019). The all-reduce then moves 1/4 the
bytes of fp32 (the pod axis is the bandwidth-poor DCN link — see DESIGN.md).

Functional API so it composes with jit/shard_map:
    state = init(grads)
    q, scales, state = compress(grads, state)
    ...all-reduce q (int32-accumulate)...
    grads = decompress(q_sum, scales_mean)
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any  # pytree like grads, fp32


def init(grads_or_struct) -> EFState:
    z = jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_or_struct)
    return EFState(residual=z)


def _q_one(g, r):
    g = g.astype(jnp.float32) + r
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    new_r = g - q.astype(jnp.float32) * scale
    return q, scale, new_r


def compress(grads, state: EFState):
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(state.residual)
    qs, scales, rs = [], [], []
    for g, r in zip(flat_g, flat_r):
        q, s, nr = _q_one(g, r)
        qs.append(q)
        scales.append(s)
        rs.append(nr)
    unf = lambda xs: jax.tree_util.tree_unflatten(tdef, xs)
    return unf(qs), unf(scales), EFState(residual=unf(rs))


def decompress(q, scales):
    return jax.tree_util.tree_map(
        lambda qq, s: qq.astype(jnp.float32) * s, q, scales)


def allreduce_compressed(grads, state: EFState, axis_name: str):
    """Inside shard_map/pmap: quantise, psum int32, dequantise with the mean
    scale. Returns (mean grads, new state)."""
    q, scales, state = compress(grads, state)
    n = jax.lax.psum(1, axis_name)
    q_sum = jax.tree_util.tree_map(
        lambda qq: jax.lax.psum(qq.astype(jnp.int32), axis_name), q)
    s_mean = jax.tree_util.tree_map(
        lambda s: jax.lax.psum(s, axis_name) / n, scales)
    g = jax.tree_util.tree_map(
        lambda qq, s: qq.astype(jnp.float32) * s / n, q_sum, s_mean)
    return g, state

"""Dense FFN blocks: SwiGLU / GeGLU (gated) and squared-ReLU / GELU MLPs."""
from __future__ import annotations

import jax

from repro.models.common import act_fn, dense_init, is_gated


def init_ffn(key, d_model: int, d_ff: int, act: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], d_model, d_ff, dtype),
         "wo": dense_init(ks[1], d_ff, d_model, dtype)}
    if is_gated(act):
        p["wg"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def ffn_forward(params, x, act: str, shard=None):
    f = act_fn(act)
    h = x @ params["wi"].astype(x.dtype)
    if is_gated(act):
        g = x @ params["wg"].astype(x.dtype)
        h = f(g) * h
    else:
        h = f(h)
    if shard is not None:
        h = shard(h)
    return h @ params["wo"].astype(x.dtype)

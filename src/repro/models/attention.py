"""Attention: blockwise (flash-style, online-softmax) full-sequence attention
with causal / sliding-window / bidirectional masks, GQA grouped heads,
single-token decode against a KV cache, and cross-attention.

FLOPs honesty: the blockwise path only visits (q-block, kv-block) pairs that
can contain unmasked entries, so causal attention costs ~S^2/2 and windowed
attention ~S*(W+Bq) — the compiled HLO reflects the sub-quadratic structure
instead of a dense masked S x S matmul.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import apply_rope, dense_init

NEG_INF = -1e30


class AttnParams(NamedTuple):
    wq: jax.Array  # (D, H*hd)
    wk: jax.Array  # (D, K*hd)
    wv: jax.Array  # (D, K*hd)
    wo: jax.Array  # (H*hd, D)


def init_attn(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
              dtype) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype,
                         scale=1.0 / np.sqrt(n_heads * head_dim)),
    }


def _block_pairs(n_q: int, n_kv: int, block_q: int, block_kv: int,
                 causal: bool, window: int) -> np.ndarray:
    """Static list of (qi, kj) block pairs that may contain unmasked entries."""
    pairs = []
    for qi in range(n_q):
        q_lo, q_hi = qi * block_q, qi * block_q + block_q - 1
        for kj in range(n_kv):
            k_lo, k_hi = kj * block_kv, kj * block_kv + block_kv - 1
            if causal and k_lo > q_hi:
                continue
            if window > 0 and k_hi < q_lo - window + 1:
                continue
            pairs.append((qi, kj))
    return np.asarray(pairs, dtype=np.int32)


def blockwise_attention(q, k, v, *, causal: bool, window: int = 0,
                        block_q: int = 512, block_kv: int = 512,
                        q_offset: int = 0):
    """Online-softmax attention over blocks.

    q: (B, Sq, K, G, d)   grouped GQA layout (H = K*G)
    k, v: (B, Skv, K, d)
    window: 0 == unlimited; else causal sliding window of that many positions.
    q_offset: absolute position of q[0] relative to k[0] (for windowed decode
    chunks); masks use absolute positions q_pos = i + q_offset.
    Returns (B, Sq, K, G, d).
    """
    B, Sq, K, G, d = q.shape
    Skv = k.shape[1]
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    # pad sequence dims to block multiples
    pq = (-Sq) % block_q
    pk = (-Skv) % block_kv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    Sqp, Skvp = Sq + pq, Skv + pk
    n_q, n_kv = Sqp // block_q, Skvp // block_kv

    pairs = _block_pairs(n_q, n_kv, block_q, block_kv, causal, window)
    scale = 1.0 / np.sqrt(d)

    out0 = jnp.zeros((B, Sqp, K, G, d), jnp.float32)
    m0 = jnp.full((B, K, G, Sqp), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sqp), jnp.float32)

    q_ids_blk = jnp.arange(block_q)
    k_ids_blk = jnp.arange(block_kv)

    def body(carry, pair):
        out, m, l = carry
        qi, kj = pair[0], pair[1]
        qs, ks_ = qi * block_q, kj * block_kv
        qb = jax.lax.dynamic_slice_in_dim(q, qs, block_q, axis=1)
        kb = jax.lax.dynamic_slice_in_dim(k, ks_, block_kv, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, ks_, block_kv, axis=1)
        # scores: (B, K, G, bq, bkv)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qb.astype(jnp.float32),
                       kb.astype(jnp.float32)) * scale
        q_pos = qs + q_ids_blk + q_offset            # absolute positions
        k_pos = ks_ + k_ids_blk
        mask = jnp.ones((block_q, block_kv), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        # mask out kv padding
        mask &= (ks_ + k_ids_blk < Skv)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)

        mb = jax.lax.dynamic_slice_in_dim(m, qs, block_q, axis=3)
        lb = jax.lax.dynamic_slice_in_dim(l, qs, block_q, axis=3)
        ob = jax.lax.dynamic_slice_in_dim(out, qs, block_q, axis=1)

        m_new = jnp.maximum(mb, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(mb - m_new)
        l_new = lb * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bqkgd", p, vb.astype(jnp.float32))
        ob_new = ob * jnp.transpose(corr, (0, 3, 1, 2))[..., None] + pv

        out = jax.lax.dynamic_update_slice_in_dim(out, ob_new, qs, axis=1)
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, qs, axis=3)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_new, qs, axis=3)
        return (out, m, l), None

    (out, m, l), _ = jax.lax.scan(body, (out0, m0, l0), jnp.asarray(pairs))
    denom = jnp.transpose(l, (0, 3, 1, 2))[..., None]
    out = out / jnp.maximum(denom, 1e-30)
    if pq:
        out = out[:, :Sq]
    return out.astype(q.dtype)


def qblock_attention(q, k, v, *, causal: bool, window: int = 0,
                     block_q: int = 512, block_kv: int = 512,
                     shard_blocks=None):
    """Query-block-PARALLEL attention: all query blocks are a batch-like dim
    (shardable over the model axis) instead of a sequential scan.

    Used when neither KV nor Q heads divide the model axis (hymba: 25 heads)
    — head sharding is impossible, but the q-block dim shards cleanly.
    Windowed layers gather a per-block KV window (static indices, fully
    local compute). Global layers scan KV blocks with online softmax and
    causal masking (≤2x the triangle FLOPs, in exchange for n-way sharding).

    q: (B, S, K, G, d); k, v: (B, S, K, d). Returns (B, S, K, G, d).
    """
    B, S, K, G, d = q.shape
    Skv = k.shape[1]
    block_q = min(block_q, S)
    pad = (-S) % block_q
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    Sp = S + pad
    nb = Sp // block_q
    qb = q.reshape(B, nb, block_q, K, G, d)
    if shard_blocks is not None:
        qb = shard_blocks(qb)
    scale = 1.0 / np.sqrt(d)
    q_pos = (jnp.arange(nb) * block_q)[:, None] + jnp.arange(block_q)[None]

    if causal and window > 0:
        wp = window + block_q
        base = (jnp.arange(nb) * block_q)[:, None] - window \
            + jnp.arange(wp)[None, :]                     # (nb, wp)
        idx = jnp.clip(base, 0, Skv - 1)
        kw = k[:, idx]                                    # (B, nb, wp, K, d)
        vw = v[:, idx]
        s = jnp.einsum("bnqkgd,bnwkd->bnkgqw", qb.astype(jnp.float32),
                       kw.astype(jnp.float32)) * scale
        mask = (base[:, None, :] <= q_pos[..., None]) \
            & (base[:, None, :] > q_pos[..., None] - window) \
            & (base >= 0)[:, None, :] & (base < Skv)[:, None, :]
        s = jnp.where(mask[None, :, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bnkgqw,bnwkd->bnqkgd", p, vw.astype(jnp.float32))
    else:
        block_kv = min(block_kv, Skv)
        pk = (-Skv) % block_kv
        if pk:
            k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        n_kv = (Skv + pk) // block_kv
        k_ids = jnp.arange(block_kv)

        def body(carry, j):
            acc, m, l = carry
            ks_ = j * block_kv
            kb = jax.lax.dynamic_slice_in_dim(k, ks_, block_kv, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, ks_, block_kv, axis=1)
            s = jnp.einsum("bnqkgd,bskd->bnkgqs", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            k_pos = ks_ + k_ids
            mask = (k_pos[None, None, :] < Skv)
            if causal:
                mask = mask & (k_pos[None, None, :] <= q_pos[:, :, None])
            s = jnp.where(mask[None, :, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            pexp = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(pexp, axis=-1)
            pv = jnp.einsum("bnkgqs,bskd->bnqkgd", pexp,
                            vb.astype(jnp.float32))
            acc = acc * jnp.moveaxis(corr, -1, 2)[..., None] + pv
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, nb, block_q, K, G, d), jnp.float32)
        m0 = jnp.full((B, nb, K, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, nb, K, G, block_q), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                      jnp.arange(n_kv))
        out = acc / jnp.maximum(jnp.moveaxis(l, -1, 2)[..., None], 1e-30)

    out = out.reshape(B, Sp, K, G, d)[:, :S]
    return out.astype(q.dtype)


def attention_scores_decode(q, k_cache, v_cache, *, pos, window: int = 0):
    """Single-token attention against a cache.

    q: (B, 1, K, G, d); k_cache/v_cache: (B, S, K, d); pos: scalar int —
    number of valid cache entries (the new token's absolute position + 1).

    Mixed precision via preferred_element_type (bf16 inputs, fp32
    accumulation) — casting the cache would let XLA hoist an fp32 convert of
    the ENTIRE stacked cache out of the layer loop (2x cache memory+traffic;
    observed on qwen3 decode, see EXPERIMENTS.md §Perf).
    """
    B, _, K, G, d = q.shape
    S = k_cache.shape[1]
    scale = 1.0 / np.sqrt(d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32) * scale
    ids = jnp.arange(S)
    valid = ids[None, :] < pos
    if window > 0:
        valid &= ids[None, :] > pos - 1 - window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def _split_heads(x, n_heads, n_kv, head_dim):
    """(B, S, H*hd) -> grouped (B, S, K, G, hd)."""
    B, S, _ = x.shape
    G = n_heads // n_kv
    return x.reshape(B, S, n_kv, G, head_dim)


def _split_kv(x, n_kv, head_dim):
    B, S, _ = x.shape
    return x.reshape(B, S, n_kv, head_dim)


def attn_forward(params, x, *, n_heads, n_kv_heads, head_dim,
                 rope_theta, positions=None, causal=True, window: int = 0,
                 block_q=512, block_kv=512, shard=None,
                 layout: str = "grouped", shard_qblocks=None):
    """Full-sequence attention (train / prefill). Returns (out, (k, v)).

    layout="expand": KV heads are replicated up to n_heads so the head dim
    can be tensor-sharded when n_kv_heads does not divide the model axis
    (grok/qwen/internlm/nemotron/llama all have K=4..8 < 16). The returned
    cache keeps the compact (B, S, K, hd) layout.
    """
    B, S, D = x.shape
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if positions is None:
        positions = jnp.arange(S)
    q = _split_heads(q, n_heads, n_kv_heads, head_dim)
    k = _split_kv(k, n_kv_heads, head_dim)
    v = _split_kv(v, n_kv_heads, head_dim)
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    cache = (k, v)
    if layout == "qblock":
        out = qblock_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               shard_blocks=shard_qblocks)
        out = out.reshape(B, S, n_heads * head_dim)
        return out @ params["wo"].astype(x.dtype), cache
    if layout == "expand":
        G = n_heads // n_kv_heads
        q = q.reshape(B, S, n_heads, 1, head_dim)
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    if shard is not None:
        q, k, v = shard(q), shard(k), shard(v)
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              block_q=block_q, block_kv=block_kv)
    out = out.reshape(B, S, n_heads * head_dim)
    return out @ params["wo"].astype(x.dtype), cache


def attn_decode(params, x, cache_k, cache_v, *, pos, n_heads, n_kv_heads,
                head_dim, rope_theta, window: int = 0, shard=None):
    """One-token decode. x: (B, 1, D); cache: (B, S, K, hd). pos: scalar —
    index where the new token is written. Returns (out, cache_k, cache_v)."""
    B, _, D = x.shape
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    q = _split_heads(q, n_heads, n_kv_heads, head_dim)
    k = _split_kv(k, n_kv_heads, head_dim)
    v = _split_kv(v, n_kv_heads, head_dim)
    if rope_theta is not None:
        p = jnp.full((1,), pos)
        q = apply_rope(q, p, rope_theta)
        k = apply_rope(k, p, rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, axis=1)
    if shard is not None:
        cache_k, cache_v = shard(cache_k), shard(cache_v)
    out = attention_scores_decode(q, cache_k, cache_v, pos=pos + 1,
                                  window=window)
    out = out.reshape(B, 1, n_heads * head_dim)
    return out @ params["wo"].astype(x.dtype), cache_k, cache_v


def cross_attn_forward(params, x, kv_src, *, n_heads, n_kv_heads, head_dim,
                       shard=None):
    """Cross attention: queries from x (B,S,D), keys/values from kv_src
    (B, T, D). Bidirectional (no mask). Returns out (B,S,D)."""
    B, S, D = x.shape
    q = x @ params["wq"].astype(x.dtype)
    k = kv_src @ params["wk"].astype(kv_src.dtype)
    v = kv_src @ params["wv"].astype(kv_src.dtype)
    q = _split_heads(q, n_heads, n_kv_heads, head_dim)
    k = _split_kv(k, n_kv_heads, head_dim)
    v = _split_kv(v, n_kv_heads, head_dim)
    if shard is not None:
        q, k, v = shard(q), shard(k), shard(v)
    out = blockwise_attention(q, k, v, causal=False, window=0,
                              block_q=512, block_kv=512)
    out = out.reshape(B, S, n_heads * head_dim)
    return out @ params["wo"].astype(x.dtype)

"""Single-token decode steps + KV/state-cache construction for all families.

``decode_step(params, cache, token, pos)`` consumes and returns the cache
functionally (callers donate it for in-place updates). ``cache_struct``
returns the ShapeDtypeStruct tree used both to allocate zeros (serving) and
as abstract dry-run inputs (no allocation).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import dtype_of, norm_apply
from repro.models.transformer import (_norm_kind, _unembed, apply_block,
                                      attn_runs)


def cache_struct(cfg: ModelConfig, batch: int, seq_len: int) -> Dict[str, Any]:
    """ShapeDtypeStruct tree of the decode cache."""
    dt = dtype_of(cfg.dtype)
    f32 = jnp.float32
    B, S, K, hd, L = batch, seq_len, cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    sds = jax.ShapeDtypeStruct
    if cfg.family == "ssm":
        per = cfg.slstm_every
        n_seg = L // per
        H, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
        return {
            "m_c": sds((n_seg, per - 1, B, H, dh, dh), f32),
            "m_n": sds((n_seg, per - 1, B, H, dh), f32),
            "m_m": sds((n_seg, per - 1, B, H), f32),
            "s_c": sds((n_seg, B, H, dh), f32),
            "s_n": sds((n_seg, B, H, dh), f32),
            "s_m": sds((n_seg, B, H, dh), f32),
            "s_h": sds((n_seg, B, H, dh), f32),
        }
    if cfg.cross_attn_every:
        n_seg = L // cfg.cross_attn_every
        inner = cfg.cross_attn_every
        return {
            "k": sds((n_seg, inner, B, S, K, hd), dt),
            "v": sds((n_seg, inner, B, S, K, hd), dt),
            "xk": sds((n_seg, B, cfg.n_vision_tokens, K, hd), dt),
            "xv": sds((n_seg, B, cfg.n_vision_tokens, K, hd), dt),
        }
    # uniform attention archs: one cache tree per homogeneous run
    runs = []
    for (n, w, th) in attn_runs(cfg):
        c = {"k": sds((n, B, S, K, hd), dt), "v": sds((n, B, S, K, hd), dt)}
        if cfg.parallel_ssm:
            di = cfg.ssm.d_inner_mult * cfg.d_model
            W, N = cfg.ssm.conv_width, cfg.ssm.state_dim
            c["mamba_conv"] = sds((n, B, W - 1, di), f32)
            c["mamba_h"] = sds((n, B, di, N), f32)
        runs.append(c)
    return {"runs": runs}


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    st = cache_struct(cfg, batch, seq_len)
    z = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), st)
    if cfg.family == "ssm":
        z["m_m"] = z["m_m"] - 1e30
        z["s_m"] = z["s_m"] - 1e30
    return z


def decode_step(params, cache, token, pos, cfg: ModelConfig, ctx=None
                ) -> Tuple[jax.Array, Any]:
    """token: (B, 1) int32; pos: scalar int32 (write index into the cache).

    Returns (logits (B, 1, V), new_cache).
    """
    x = params["embed"][token].astype(dtype_of(cfg.dtype))

    if cfg.family == "ssm":
        x, cache = _xlstm_decode(params, cache, x, cfg, ctx)
    elif cfg.cross_attn_every:
        x, cache = _vlm_decode(params, cache, x, pos, cfg, ctx)
    else:
        new_runs = []
        for run_p, run_c, (n, w, th) in zip(params["blocks"], cache["runs"],
                                            attn_runs(cfg)):
            def body(xc, inp, _w=w, _th=th):
                blk, c = inp
                y, c2 = apply_block(blk, xc, cfg, window=_w, theta=_th,
                                    ctx=ctx, mode="decode", cache=c, pos=pos)
                return y, c2

            x, c_new = jax.lax.scan(body, x, (run_p, run_c))
            new_runs.append(c_new)
        cache = {"runs": new_runs}

    x = norm_apply(params["norm_f"], x, _norm_kind(cfg), cfg.norm_eps)
    logits = _unembed(params, cfg, x)
    if ctx:
        logits = ctx.act_logits(logits)
    return logits, cache


def _vlm_decode(params, cache, x, pos, cfg, ctx):
    def seg_body(xc, inp):
        blks, cross, ck, cv, xk, xv = inp

        def inner_body(xi, binp):
            blk, c_k, c_v = binp
            y, c2 = apply_block(blk, xi, cfg, window=0, theta=cfg.rope_theta,
                                ctx=ctx, mode="decode",
                                cache={"k": c_k, "v": c_v}, pos=pos)
            return y, (c2["k"], c2["v"])

        xc, (nk, nv) = jax.lax.scan(inner_body, xc, (blks, ck, cv))
        h = norm_apply(cross["norm"], xc, "rms", cfg.norm_eps)
        q = h @ cross["attn"]["wq"].astype(h.dtype)
        B = q.shape[0]
        q = q.reshape(B, 1, cfg.n_kv_heads,
                      cfg.n_heads // cfg.n_kv_heads, cfg.head_dim)
        o = attn.attention_scores_decode(q, xk, xv,
                                         pos=cfg.n_vision_tokens)
        o = o.reshape(B, 1, cfg.n_heads * cfg.head_dim)
        o = o @ cross["attn"]["wo"].astype(h.dtype)
        xc = xc + jnp.tanh(cross["gate"]).astype(xc.dtype) * o
        return xc, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        seg_body, x,
        (params["blocks"], params["cross"],
         cache["k"], cache["v"], cache["xk"], cache["xv"]))
    cache = dict(cache, k=nk, v=nv)
    return x, cache


def _xlstm_decode(params, cache, x, cfg, ctx):
    def seg_body(xc, inp):
        mblks, sblk, mc, mn, mm, sc, sn, sm, sh = inp

        def m_body(xi, binp):
            blk, c, n, m = binp
            st = xlstm_mod.MLSTMState(c=c, n=n, m=m)
            h = norm_apply(blk["norm"], xi, "rms", cfg.norm_eps)
            y, st = xlstm_mod.mlstm_step(blk["m"], h, st,
                                         n_heads=cfg.n_heads)
            return xi + y, (st.c, st.n, st.m)

        xc, mstates = jax.lax.scan(m_body, xc, (mblks, mc, mn, mm))
        h = norm_apply(sblk["norm"], xc, "rms", cfg.norm_eps)
        st = xlstm_mod.SLSTMState(c=sc, n=sn, m=sm, h=sh)
        y, st = xlstm_mod.slstm_step(sblk["s"], h, st, n_heads=cfg.n_heads)
        xc = xc + y
        return xc, (mstates, (st.c, st.n, st.m, st.h))

    x, (ms, ss) = jax.lax.scan(
        seg_body, x,
        (params["mblocks"], params["sblocks"], cache["m_c"], cache["m_n"],
         cache["m_m"], cache["s_c"], cache["s_n"], cache["s_m"],
         cache["s_h"]))
    cache = {"m_c": ms[0], "m_n": ms[1], "m_m": ms[2],
             "s_c": ss[0], "s_n": ss[1], "s_m": ss[2], "s_h": ss[3]}
    return x, cache


def prefill(params, batch, cfg: ModelConfig, ctx=None):
    """Full-sequence prefill. Returns (last-token logits, cache or None)."""
    from repro.models.transformer import forward
    h, caches = forward(params, batch, cfg, ctx, mode="prefill")
    logits = _unembed(params, cfg, h[:, -1:])
    if ctx:
        logits = ctx.act_logits(logits)
    if cfg.encoder_only:
        return logits, None
    return logits, caches

"""Shared building blocks: norms, RoPE, initialisers, dtype policy."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    """Truncated-normal fan-in init."""
    if scale is None:
        scale = 1.0 / np.sqrt(d_in)
    w = jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out), jnp.float32)
    return (w * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    w = jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d), jnp.float32)
    return (w * 0.02).astype(dtype)


def rms_norm(x, w, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, w, b, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def norm_apply(params, x, kind: str, eps: float):
    if kind == "ln":
        return layer_norm(x, params["w"], params["b"], eps)
    return rms_norm(x, params["w"], eps)


def norm_init(d: int, kind: str, dtype):
    if kind == "ln":
        return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    return {"w": jnp.zeros((d,), dtype)}  # rms: stored as (1 + w)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta):
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # (head_dim/2,)


def apply_rope(x, positions, theta):
    """x: (..., S, ..., head_dim) with positions broadcastable to x's S dim.

    positions: (S,) or (B, S). x layout: (B, S, K, G, d) or (B, S, K, d).
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (d/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, d/2)
    # reshape angles to broadcast over head dims between S and d
    extra = x.ndim - angles.ndim - 1
    for _ in range(extra):
        angles = angles[..., None, :]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def act_fn(name: str):
    if name in ("swiglu",):
        return jax.nn.silu
    if name == "geglu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def is_gated(name: str) -> bool:
    return name in ("swiglu", "geglu")


def cross_entropy(logits, labels, ignore_id: int = -1):
    """Stable CE in fp32 over (possibly sharded) vocab dim. Returns mean loss."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    mask = (labels != ignore_id).astype(jnp.float32)
    nll = (lse - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)

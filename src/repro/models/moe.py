"""Mixture-of-Experts FFN with explicit expert/tensor parallelism via
``shard_map``.

Two parallel modes (chosen per architecture, see configs):

- ``ep``  — experts sharded over the ``model`` mesh axis (requires
  num_experts % tp == 0; e.g. qwen3: 128 experts over 16 => 8/device).
  Each device dispatches the tokens routed to ITS experts into a
  (E_loc, C, D) capacity buffer, runs the expert FFN, scatters back, and the
  per-device partial outputs are summed with ``psum`` over ``model``.
- ``tp``  — every device holds all experts but the expert d_ff is sharded
  over ``model`` (grok-1: 8 experts < 16 devices). The d_ff partial products
  are summed with ``psum`` over ``model``.

Both modes implement FSDP explicitly: expert weights arrive sharded over the
``data`` axis on the d_model dim and are all-gathered just-in-time inside the
shard_map body (the gather is the FSDP weight collection, overlappable by the
compiler with the dispatch compute).

Token dispatch is the sort-based capacity-buffer scheme (Switch-style, with
dropping): O(t log t) sort + O(t) scatter, no (tokens x experts x capacity)
dispatch tensor is ever materialised.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.common import act_fn, dense_init, is_gated


def init_moe(key, d_model: int, cfg, act: str, dtype) -> dict:
    """cfg: MoEConfig."""
    ks = jax.random.split(key, 4)
    E, F = cfg.num_experts, cfg.d_ff_expert
    p = {
        "router": dense_init(ks[0], d_model, E, jnp.float32),
        "wi": _einit(ks[1], E, d_model, F, dtype),
        "wo": _einit(ks[2], E, F, d_model, dtype),
    }
    if is_gated(act):
        p["wg"] = _einit(ks[3], E, d_model, F, dtype)
    return p


def _einit(key, e, din, dout, dtype):
    w = jax.random.truncated_normal(key, -2.0, 2.0, (e, din, dout), jnp.float32)
    return (w / np.sqrt(din)).astype(dtype)


def capacity_for(tokens_local: int, num_experts: int, top_k: int,
                 capacity_factor: float) -> int:
    """Per-slot, per-expert capacity for a device-local token count."""
    c = int(np.ceil(tokens_local * capacity_factor / num_experts))
    c = max(c, min(tokens_local, 8))
    return min(c, tokens_local)


def _dispatch_compute(x_flat, expert_of_tok, wi, wg, wo, *, n_local: int,
                      local_off, capacity: int, act: str):
    """Route tokens to local experts via sort + capacity buffer; run FFN.

    x_flat: (t, D); expert_of_tok: (t,) global expert id for this slot.
    wi/wg: (E_loc, D, F); wo: (E_loc, F, D). local experts are
    [local_off, local_off + n_local). Returns (t, D) per-token output
    (zeros for tokens not local to this device or dropped).
    """
    t, D = x_flat.shape
    f = act_fn(act)
    local_e = expert_of_tok - local_off
    is_local = (local_e >= 0) & (local_e < n_local)
    key = jnp.where(is_local, local_e, n_local)          # sentinel at end
    order = jnp.argsort(key)                              # stable
    sorted_e = key[order]
    counts = jnp.bincount(key, length=n_local + 1)
    seg_start = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                 jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t) - seg_start[sorted_e]
    valid = (sorted_e < n_local) & (pos < capacity)
    slot = jnp.where(valid, sorted_e * capacity + pos, n_local * capacity)
    x_sorted = x_flat[order]
    buf = jnp.zeros((n_local * capacity, D), x_flat.dtype)
    buf = buf.at[slot].set(jnp.where(valid[:, None], x_sorted, 0),
                           mode="drop")
    buf = buf.reshape(n_local, capacity, D)

    h = jnp.einsum("ecd,edf->ecf", buf, wi.astype(buf.dtype))
    if wg is not None:
        g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(buf.dtype))
        h = f(g) * h
    else:
        h = f(h)
    y = jnp.einsum("ecf,efd->ecd", h, wo.astype(h.dtype))
    y_flat = y.reshape(n_local * capacity, D)

    out_sorted = jnp.where(valid[:, None],
                           y_flat[jnp.minimum(slot, n_local * capacity - 1)],
                           0)
    out = jnp.zeros_like(x_flat).at[order].set(out_sorted)
    return out


def moe_forward(params, x, *, cfg, act: str, mesh, batch_axes: Tuple[str, ...],
                fsdp_axis: str = "data", model_axis: str = "model",
                weight_stationary: bool = False):
    """MoE FFN. x: (B, S, D) sharded over batch_axes. Returns (B, S, D).

    weight_stationary=True (decode-optimised path): expert weights are NEVER
    gathered — tokens are all-gathered over the fsdp axis (tiny at decode
    batch sizes), each device computes with its D-shard of the weights, and
    partial products are psum'd over the fsdp axis. Turns the per-step
    weight movement (params/16 per device) into one activation collective.
    """
    E, K = cfg.num_experts, cfg.top_k
    tp = mesh.shape[model_axis]
    mode = cfg.parallel_mode
    if mode == "ep" and E % tp != 0:
        mode = "tp"

    wg = params.get("wg")
    gated = wg is not None

    if mode == "ep":
        wspec = P(model_axis, fsdp_axis, None)
        wospec = P(model_axis, None, fsdp_axis)
    else:
        wspec = P(None, fsdp_axis, model_axis)
        wospec = P(None, model_axis, fsdp_axis)

    xspec = P(batch_axes, None, None)

    dp_total = int(np.prod([mesh.shape[a] for a in batch_axes]))
    dp_fsdp = int(mesh.shape[fsdp_axis]) if fsdp_axis else 1
    B, S, D = x.shape
    t_local = max(1, (B // dp_total) * S)
    cap = capacity_for(t_local, E, K, cfg.capacity_factor)
    cap_ws = capacity_for(t_local * dp_fsdp, E, K, cfg.capacity_factor)

    def body_ws(x_loc, router, wi, wg_, wo):
        """Weight-stationary: gather tokens, never gather weights."""
        b, s, d = x_loc.shape
        t_loc = b * s
        xf = x_loc.reshape(t_loc, d)
        x_all = jax.lax.all_gather(xf, fsdp_axis, axis=0, tiled=True)
        t_all = t_loc * dp_fsdp

        logits = x_all.astype(jnp.float32) @ router
        topv, topi = jax.lax.top_k(logits, K)
        cw = jax.nn.softmax(topv, axis=-1)

        rm = jax.lax.axis_index(model_axis)
        rd = jax.lax.axis_index(fsdp_axis)
        d_loc = wi.shape[1]
        x_slice = jax.lax.dynamic_slice_in_dim(x_all, rd * d_loc, d_loc,
                                               axis=1)
        if mode == "ep":
            n_local = E // tp
            local_off = rm * n_local
        else:
            n_local = E
            local_off = jnp.zeros((), jnp.int32)

        f = act_fn(act)
        acc = jnp.zeros((t_all, d_loc), x_loc.dtype)
        for j in range(K):
            # dispatch D-sliced tokens into the capacity buffer
            local_e = topi[:, j] - local_off
            is_local = (local_e >= 0) & (local_e < n_local)
            key = jnp.where(is_local, local_e, n_local)
            order = jnp.argsort(key)
            sorted_e = key[order]
            counts = jnp.bincount(key, length=n_local + 1)
            seg_start = jnp.concatenate(
                [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
            pos = jnp.arange(t_all) - seg_start[sorted_e]
            valid = (sorted_e < n_local) & (pos < cap_ws)
            slot = jnp.where(valid, sorted_e * cap_ws + pos,
                             n_local * cap_ws)
            buf = jnp.zeros((n_local * cap_ws, d_loc), x_loc.dtype)
            buf = buf.at[slot].set(
                jnp.where(valid[:, None], x_slice[order], 0), mode="drop")
            buf = buf.reshape(n_local, cap_ws, d_loc)

            h = jnp.einsum("ecd,edf->ecf", buf, wi.astype(buf.dtype))
            h = jax.lax.psum(h, fsdp_axis)        # complete D contraction
            if gated:
                g = jnp.einsum("ecd,edf->ecf", buf, wg_.astype(buf.dtype))
                g = jax.lax.psum(g, fsdp_axis)
                h = f(g) * h
            else:
                h = f(h)
            y = jnp.einsum("ecf,efd->ecd", h, wo.astype(h.dtype))
            if mode != "ep":
                y = jax.lax.psum(y, model_axis)   # complete F contraction
            y_flat = y.reshape(n_local * cap_ws, d_loc)
            out_sorted = jnp.where(
                valid[:, None],
                y_flat[jnp.minimum(slot, n_local * cap_ws - 1)], 0)
            outj = jnp.zeros((t_all, d_loc), x_loc.dtype) \
                .at[order].set(out_sorted)
            acc = acc + cw[:, j, None].astype(acc.dtype) * outj
        if mode == "ep":
            acc = jax.lax.psum(acc, model_axis)   # combine expert groups
        # back to this device's tokens and full D
        mine = jax.lax.dynamic_slice_in_dim(acc, rd * t_loc, t_loc, axis=0)
        mine = jax.lax.all_gather(mine, fsdp_axis, axis=1, tiled=True)
        return mine.reshape(b, s, d)

    def body(x_loc, router, wi, wg_, wo):
        b, s, d = x_loc.shape
        xf = x_loc.reshape(b * s, d)
        # FSDP: collect the d_model (and for tp-mode d_ff) shards of weights
        wi = jax.lax.all_gather(wi, fsdp_axis, axis=1 if mode == "ep" else 1,
                                tiled=True)
        wo = jax.lax.all_gather(wo, fsdp_axis, axis=2 if mode == "ep" else 2,
                                tiled=True)
        if gated:
            wg_full = jax.lax.all_gather(wg_, fsdp_axis, axis=1, tiled=True)
        else:
            wg_full = None

        logits = (xf.astype(jnp.float32) @ router)          # (t, E)
        topv, topi = jax.lax.top_k(logits, K)
        cw = jax.nn.softmax(topv, axis=-1)                   # (t, K)

        r = jax.lax.axis_index(model_axis)
        if mode == "ep":
            n_local = E // tp
            local_off = r * n_local
        else:
            n_local = E
            local_off = jnp.zeros((), jnp.int32)

        acc = jnp.zeros_like(xf)
        for j in range(K):
            outj = _dispatch_compute(
                xf, topi[:, j], wi, wg_full, wo, n_local=n_local,
                local_off=local_off, capacity=cap, act=act)
            acc = acc + cw[:, j, None].astype(acc.dtype) * outj
        acc = jax.lax.psum(acc, model_axis)
        return acc.reshape(b, s, d)

    from jax.experimental.shard_map import shard_map

    fn = shard_map(
        body_ws if weight_stationary else body, mesh=mesh,
        in_specs=(xspec, P(None, None), wspec,
                  (wspec if gated else P()), wospec),
        out_specs=xspec,
        check_rep=False,
    )
    wg_arg = wg if gated else jnp.zeros((), x.dtype)
    return fn(x, params["router"], params["wi"], wg_arg, params["wo"])


def moe_ref(params, x, *, cfg, act: str):
    """Dense reference (no dropping, no parallelism) for tests."""
    E, K = cfg.num_experts, cfg.top_k
    f = act_fn(act)
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    logits = xf.astype(jnp.float32) @ params["router"]
    topv, topi = jax.lax.top_k(logits, K)
    cw = jax.nn.softmax(topv, axis=-1)
    out = jnp.zeros_like(xf)
    for e in range(E):
        h = xf @ params["wi"][e].astype(xf.dtype)
        if "wg" in params:
            g = xf @ params["wg"][e].astype(xf.dtype)
            h = f(g) * h
        else:
            h = f(h)
        y = h @ params["wo"][e].astype(h.dtype)
        w_e = jnp.sum(jnp.where(topi == e, cw, 0.0), axis=-1)
        out = out + w_e[:, None].astype(out.dtype) * y
    return out.reshape(B, S, D)

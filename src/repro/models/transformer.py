"""Unified model: dense / MoE / SSM (xLSTM) / hybrid (hymba) / VLM / audio.

All layer stacks are ``lax.scan`` over stacked parameters (compile-time O(1)
in depth). Heterogeneous stacks use segment nesting:

- vlm:   scan over n_seg segments, each = inner scan over `cross_attn_every`
         self-attn blocks followed by one cross-attn block.
- ssm:   scan over n_seg segments, each = inner scan over (slstm_every - 1)
         mLSTM blocks followed by one sLSTM block.
- gemma3 local:global and hymba window patterns are handled *inside* a
  homogeneous scan via per-layer (window, rope_theta) scanned metadata.

Training loss uses sequence-chunked cross-entropy: full (B, S, V) logits are
never materialised (the unembed matmul is folded into a scan over sequence
chunks) — a large activation-memory win at 256k vocabularies.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import (cross_entropy, dtype_of, embed_init,
                                 norm_apply, norm_init)

Params = Dict[str, Any]


def _norm_kind(cfg: ModelConfig) -> str:
    return "ln" if cfg.family == "audio" else "rms"


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig):
    """One transformer block (self-attn [+ssm] + ffn/moe)."""
    dt = dtype_of(cfg.param_dtype)
    nk = _norm_kind(cfg)
    ks = jax.random.split(key, 4)
    p = {"norm1": norm_init(cfg.d_model, nk, dt)}
    if cfg.family == "ssm":
        raise AssertionError("ssm handled separately")
    p["attn"] = attn.init_attn(ks[0], cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.head_dim, dt)
    if cfg.parallel_ssm:
        p["mamba"] = ssm_mod.init_mamba(ks[1], cfg.d_model, cfg.ssm, dt)
        p["norm_attn_o"] = norm_init(cfg.d_model, nk, dt)
        p["norm_ssm_o"] = norm_init(cfg.d_model, nk, dt)
    p["norm2"] = norm_init(cfg.d_model, nk, dt)
    if cfg.moe is not None:
        p["moe"] = moe_mod.init_moe(ks[2], cfg.d_model, cfg.moe, cfg.act, dt)
    elif cfg.d_ff:
        p["ffn"] = ffn_mod.init_ffn(ks[2], cfg.d_model, cfg.d_ff, cfg.act, dt)
    return p


def attn_runs(cfg: ModelConfig):
    """Group consecutive layers with equal (window, rope_theta) into runs.

    Returns a list of (length, window, theta) — windows stay STATIC so the
    blockwise-attention structure is sub-quadratic where the pattern says so.
    """
    L = cfg.n_layers
    tg = cfg.rope_theta_global or cfg.rope_theta
    metas = []
    for i in range(L):
        w = cfg.window_for_layer(i)
        metas.append((w, tg if w == 0 else cfg.rope_theta))
    runs = []
    for w, th in metas:
        if runs and runs[-1][1] == w and runs[-1][2] == th:
            runs[-1][0] += 1
        else:
            runs.append([1, w, th])
    return [tuple(r) for r in runs]


def apply_block(p, x, cfg: ModelConfig, *, window, theta, ctx,
                positions=None, mode: str = "train",
                cache: Optional[dict] = None, pos=None):
    """One block. mode: train|prefill (full-seq) or decode (one token).

    Returns (x, new_cache_entry) where new_cache_entry is None in train mode.
    """
    nk, eps = _norm_kind(cfg), cfg.norm_eps
    h = norm_apply(p["norm1"], x, nk, eps)
    shard = (lambda t: ctx.act_kv(t)) if ctx else None
    layout = ctx.attn_layout(cfg.n_heads, cfg.n_kv_heads) if ctx \
        else "grouped"
    new_cache = {}
    if mode in ("train", "prefill"):
        a_out, (k, v) = attn.attn_forward(
            p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, rope_theta=theta, positions=positions,
            causal=not cfg.encoder_only, window=window, shard=shard,
            layout=layout,
            shard_qblocks=(lambda t: ctx.act_qblocks(t)) if ctx else None)
        if mode == "prefill":
            new_cache["k"], new_cache["v"] = k, v
    else:
        a_out, ck, cv = attn.attn_decode(
            p["attn"], h, cache["k"], cache["v"], pos=pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, rope_theta=theta, window=window,
            shard=shard)
        new_cache["k"], new_cache["v"] = ck, cv

    if cfg.parallel_ssm:
        if mode in ("train", "prefill"):
            s_out, s_state = ssm_mod_forward_with_state(p["mamba"], h, cfg)
            if mode == "prefill":
                new_cache["mamba_conv"] = s_state.conv
                new_cache["mamba_h"] = s_state.h
        else:
            st = ssm_mod.MambaState(conv=cache["mamba_conv"],
                                    h=cache["mamba_h"])
            s_out, st = ssm_mod.mamba_step(p["mamba"], h, st, cfg=cfg.ssm)
            new_cache["mamba_conv"], new_cache["mamba_h"] = st.conv, st.h
        a_out = 0.5 * (norm_apply(p["norm_attn_o"], a_out, nk, eps)
                       + norm_apply(p["norm_ssm_o"], s_out, nk, eps))
    x = x + a_out
    if ctx:
        x = ctx.act_btd(x)

    h2 = norm_apply(p["norm2"], x, nk, eps)
    if cfg.moe is not None:
        f_out = moe_mod.moe_forward(
            p["moe"], h2, cfg=cfg.moe, act=cfg.act, mesh=ctx.mesh,
            batch_axes=ctx.batch_axes,
            fsdp_axis=ctx.fsdp_axis or "data",
            weight_stationary=ctx.moe_weight_stationary) if ctx else \
            moe_mod.moe_ref(p["moe"], h2, cfg=cfg.moe, act=cfg.act)
    elif cfg.d_ff:
        f_out = ffn_mod.ffn_forward(
            p["ffn"], h2, cfg.act,
            shard=(lambda t: ctx.act_ff(t)) if ctx else None)
    else:
        f_out = 0.0
    x = x + f_out
    if ctx:
        x = ctx.act_btd(x)
    return x, (new_cache or None)


def ssm_mod_forward_with_state(params, x, cfg: ModelConfig):
    """mamba_forward + final state (for prefill)."""
    y = ssm_mod.mamba_forward(params, x, cfg=cfg.ssm)
    # recompute final state cheaply from the last conv_width tokens + rerun?
    # For prefill we need exact state: run a short suffix pass — the scan in
    # mamba_forward already has it, so we expose it via the step path on the
    # last token only when required. To keep one code path, recompute state
    # by scanning the final chunk is equivalent; here we fold it directly:
    st = ssm_mod.mamba_prefill_state(params, x, cfg=cfg.ssm)
    return y, st


# ---------------------------------------------------------------------------
# xLSTM blocks
# ---------------------------------------------------------------------------


def init_xlstm_mblock(key, cfg: ModelConfig):
    dt = dtype_of(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {"norm": norm_init(cfg.d_model, "rms", dt),
            "m": xlstm_mod.init_mlstm(k1, cfg.d_model, cfg.n_heads, dt)}


def init_xlstm_sblock(key, cfg: ModelConfig):
    dt = dtype_of(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {"norm": norm_init(cfg.d_model, "rms", dt),
            "s": xlstm_mod.init_slstm(k1, cfg.d_model, cfg.n_heads, dt)}


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, rng) -> Params:
    dt = dtype_of(cfg.param_dtype)
    keys = jax.random.split(rng, 8)
    p: Params = {}
    p["embed"] = embed_init(keys[0], cfg.vocab, cfg.d_model, dt)
    if not cfg.tie_embeddings:
        p["unembed"] = embed_init(keys[1], cfg.vocab, cfg.d_model, dt)
    p["norm_f"] = norm_init(cfg.d_model, _norm_kind(cfg), dt)

    if cfg.family == "ssm":
        per = cfg.slstm_every or (cfg.n_layers + 1)
        n_seg, rem = divmod(cfg.n_layers, per)
        assert rem == 0, "ssm stack must divide into (m*(per-1)+s) segments"
        mk = jax.random.split(keys[2], n_seg * (per - 1)).reshape(
            n_seg, per - 1, 2)
        p["mblocks"] = jax.vmap(jax.vmap(
            lambda k: init_xlstm_mblock(k, cfg)))(mk)
        sk = jax.random.split(keys[3], n_seg)
        p["sblocks"] = jax.vmap(lambda k: init_xlstm_sblock(k, cfg))(sk)
        return p

    if cfg.cross_attn_every:
        n_seg, rem = divmod(cfg.n_layers, cfg.cross_attn_every)
        assert rem == 0
        bk = jax.random.split(keys[2], n_seg * cfg.cross_attn_every).reshape(
            n_seg, cfg.cross_attn_every, 2)
        p["blocks"] = jax.vmap(jax.vmap(lambda k: init_block(k, cfg)))(bk)
        ck = jax.random.split(keys[3], n_seg)

        def init_cross(k):
            kk = jax.random.split(k, 2)
            return {"norm": norm_init(cfg.d_model, "rms", dt),
                    "attn": attn.init_attn(kk[0], cfg.d_model, cfg.n_heads,
                                           cfg.n_kv_heads, cfg.head_dim, dt),
                    "gate": jnp.zeros((1,), jnp.float32)}

        p["cross"] = jax.vmap(init_cross)(ck)
        return p

    # homogeneous runs of equal (window, theta): one stacked scan per run
    runs = attn_runs(cfg)
    all_keys = jax.random.split(keys[2], cfg.n_layers)
    blocks, off = [], 0
    for (n, _, _) in runs:
        ks_run = all_keys[off:off + n]
        blocks.append(jax.vmap(lambda k: init_block(k, cfg))(ks_run))
        off += n
    p["blocks"] = blocks
    return p


def _embed_in(params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    if cfg.embedding_inputs:
        return batch["embeds"]
    x = params["embed"][batch["tokens"]]
    return x.astype(dtype_of(cfg.dtype))


def _unembed(params, cfg: ModelConfig, x):
    w = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return x @ w.astype(x.dtype).T


def forward(params: Params, batch, cfg: ModelConfig, ctx=None,
            mode: str = "train"):
    """Full-sequence forward. Returns (h_final, aux) where h_final is the
    pre-unembed hidden state; aux carries the prefill cache if requested."""
    x = _embed_in(params, cfg, batch)
    if ctx:
        x = ctx.act_btd(x)
    S = x.shape[1]
    positions = jnp.arange(S)
    collect = mode == "prefill"

    if cfg.family == "ssm":
        x, aux = _xlstm_stack(params, x, cfg, ctx, collect)
    elif cfg.cross_attn_every:
        x, aux = _vlm_stack(params, x, batch["vision_embeds"], cfg, ctx,
                            positions, collect)
    else:
        aux = []
        for run_p, (n, w, th) in zip(params["blocks"], attn_runs(cfg)):
            def body(xc, blk, _w=w, _th=th):
                y, c = apply_block(blk, xc, cfg, window=_w, theta=_th,
                                   ctx=ctx, positions=positions,
                                   mode="prefill" if collect else "train")
                return y, c

            x, caches = _scan_run(body, x, run_p, cfg, n)
            aux.append(caches)
        if not collect:
            aux = None
    x = norm_apply(params["norm_f"], x, _norm_kind(cfg), cfg.norm_eps)
    return x, aux


def _scan_run(body, x, run_p, cfg: ModelConfig, n: int):
    """Scan a homogeneous run; two-level (grouped) remat when configured.

    Grouped remat (e.g. nemotron: 96 = 12 groups x 8 layers) saves one
    residual per GROUP instead of per layer; group internals recompute during
    backward with per-layer remat — peak saved-activation memory drops
    ~n/groups x at ~2x recompute of the inner forward.
    """
    g = cfg.remat_groups
    if g and n % g == 0 and n > g:
        inner = n // g
        grouped = jax.tree_util.tree_map(
            lambda p: p.reshape((g, inner) + p.shape[1:]), run_p)

        def outer(xc, gp):
            return jax.lax.scan(_remat(body, cfg), xc, gp)

        x, caches = jax.lax.scan(jax.checkpoint(outer), x, grouped)
        caches = jax.tree_util.tree_map(
            lambda c: c.reshape((n,) + c.shape[2:]) if c is not None else c,
            caches)
        return x, caches
    return jax.lax.scan(_remat(body, cfg), x, run_p)


def _vlm_stack(params, x, vis, cfg, ctx, positions, collect):
    shard = (lambda t: ctx.act_kv(t)) if ctx else None

    def seg_body(xc, inp):
        blks, cross = inp

        def inner_body(xi, blk):
            y, c = apply_block(blk, xi, cfg, window=0, theta=cfg.rope_theta,
                               ctx=ctx, positions=positions,
                               mode="prefill" if collect else "train")
            return y, c

        xc, caches = jax.lax.scan(_remat(inner_body, cfg), xc, blks)
        h = norm_apply(cross["norm"], xc, "rms", cfg.norm_eps)
        c_out = attn.cross_attn_forward(
            cross["attn"], h, vis, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim, shard=shard)
        xc = xc + jnp.tanh(cross["gate"]).astype(xc.dtype) * c_out
        return xc, caches

    x, caches = jax.lax.scan(seg_body, x,
                             (params["blocks"], params["cross"]))
    return x, caches


def _xlstm_stack(params, x, cfg, ctx, collect):
    chunk = cfg.ssm.chunk if cfg.ssm else 128

    def seg_body(xc, inp):
        mblks, sblk = inp

        def m_body(xi, blk):
            h = xlstm_mod.mlstm_forward(
                blk["m"], norm_apply(blk["norm"], xi, "rms", cfg.norm_eps),
                n_heads=cfg.n_heads, chunk=chunk)
            y = xi + h
            if ctx:
                y = ctx.act_btd(y)
            return y, None

        xc, _ = jax.lax.scan(_remat(m_body, cfg), xc, mblks)
        h_in = norm_apply(sblk["norm"], xc, "rms", cfg.norm_eps)
        if ctx is not None and ctx.slstm_local_grad:
            h = xlstm_mod.slstm_forward_sharded(
                sblk["s"], h_in, n_heads=cfg.n_heads, mesh=ctx.mesh,
                batch_axes=ctx.batch_axes)
        else:
            h = xlstm_mod.slstm_forward(sblk["s"], h_in,
                                        n_heads=cfg.n_heads)
        xc = xc + h
        if ctx:
            xc = ctx.act_btd(xc)
        return xc, None

    x, _ = jax.lax.scan(seg_body, x, (params["mblocks"], params["sblocks"]))
    # prefill state for ssm is recomputed by the decode driver (serve.engine)
    return x, None


# ---------------------------------------------------------------------------
# Loss (chunked CE)
# ---------------------------------------------------------------------------


def loss_fn(params: Params, batch, cfg: ModelConfig, ctx=None,
            ce_chunk: int = 1024):
    """Next-token (or masked, for encoder) CE loss with chunked unembed."""
    h, _ = forward(params, batch, cfg, ctx, mode="train")
    if cfg.encoder_only:
        labels = batch["labels"]
        h_in, lab = h, labels
    else:
        h_in = h[:, :-1]
        lab = batch["labels"][:, 1:] if "labels" in batch \
            else batch["tokens"][:, 1:]
    B, S, D = h_in.shape
    ce_chunk = min(ce_chunk, S)
    pad = (-S) % ce_chunk
    if pad:
        h_in = jnp.pad(h_in, ((0, 0), (0, pad), (0, 0)))
        lab = jnp.pad(lab, ((0, 0), (0, pad)), constant_values=-1)
    nc = (S + pad) // ce_chunk
    h_c = h_in.reshape(B, nc, ce_chunk, D).swapaxes(0, 1)
    l_c = lab.reshape(B, nc, ce_chunk).swapaxes(0, 1)

    def body(acc, inp):
        hc, lc = inp
        logits = _unembed(params, cfg, hc)
        if ctx:
            logits = ctx.act_logits(logits)
        logits = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None].astype(jnp.int32),
            axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        return (acc[0] + jnp.sum((lse - ll) * mask),
                acc[1] + jnp.sum(mask)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (h_c, l_c))
    return tot / jnp.maximum(cnt, 1.0)


def logits_fn(params: Params, batch, cfg: ModelConfig, ctx=None):
    """Full logits (for tests / small-scale evaluation)."""
    h, _ = forward(params, batch, cfg, ctx, mode="train")
    return _unembed(params, cfg, h)

"""Model registry: the public entry point for building any assigned arch."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config
from repro.models import decode as decode_mod
from repro.models import transformer as tf_mod


class Model(NamedTuple):
    """Bundle of pure functions for one architecture."""

    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[..., jax.Array]
    logits: Callable[..., jax.Array]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    cache_struct: Callable[[int, int], Any]
    init_cache: Callable[[int, int], Any]


def build_model(cfg_or_arch, ctx=None) -> Model:
    """Build a Model for a ModelConfig or an assigned architecture id."""
    cfg = (cfg_or_arch if isinstance(cfg_or_arch, ModelConfig)
           else get_config(cfg_or_arch))
    return Model(
        cfg=cfg,
        init=functools.partial(tf_mod.init_params, cfg),
        loss=functools.partial(tf_mod.loss_fn, cfg=cfg, ctx=ctx),
        logits=functools.partial(tf_mod.logits_fn, cfg=cfg, ctx=ctx),
        prefill=functools.partial(decode_mod.prefill, cfg=cfg, ctx=ctx),
        decode_step=functools.partial(decode_mod.decode_step, cfg=cfg,
                                      ctx=ctx),
        cache_struct=functools.partial(decode_mod.cache_struct, cfg),
        init_cache=functools.partial(decode_mod.init_cache, cfg),
    )


def make_inputs(cfg: ModelConfig, batch: int, seq_len: int, rng=None,
                abstract: bool = False) -> Dict[str, Any]:
    """Training/prefill batch: concrete (random) or abstract (SDS).

    Modality frontends are STUBS per the assignment: audio/vlm receive
    precomputed frame/patch embeddings.
    """
    import numpy as np

    def mk(shape, dtype, hi=None):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        if rng is None:
            r = np.random.default_rng(0)
        else:
            r = rng
        if jnp.issubdtype(dtype, jnp.integer):
            return jnp.asarray(r.integers(0, hi or cfg.vocab, shape),
                               dtype=dtype)
        return jnp.asarray(r.standard_normal(shape), dtype=dtype)

    batch_d: Dict[str, Any] = {}
    if cfg.embedding_inputs:
        batch_d["embeds"] = mk((batch, seq_len, cfg.d_model),
                               jnp.bfloat16 if cfg.dtype == "bfloat16"
                               else jnp.float32)
        batch_d["labels"] = mk((batch, seq_len), jnp.int32)
    else:
        batch_d["tokens"] = mk((batch, seq_len), jnp.int32)
        batch_d["labels"] = mk((batch, seq_len), jnp.int32)
    if cfg.cross_attn_every:
        batch_d["vision_embeds"] = mk(
            (batch, cfg.n_vision_tokens, cfg.d_model),
            jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    return batch_d

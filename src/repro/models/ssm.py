"""Mamba-style selective SSM head (used by hymba's parallel attn+SSM layers).

Training/prefill uses a chunkwise formulation: the sequential scan runs over
chunks (T/chunk steps) with dense intra-chunk compute, keeping the while-loop
trip count low and compute dense. Decode is a single recurrent step with
carried (conv_state, ssm_state).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init


def init_mamba(key, d_model: int, cfg, dtype) -> dict:
    di = cfg.d_inner_mult * d_model
    N = cfg.state_dim
    ks = jax.random.split(key, 6)
    # S4D-real initialisation for A
    a = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "w_in": dense_init(ks[0], d_model, 2 * di, dtype),   # x and z branches
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, di), jnp.float32)
                   * 0.1).astype(dtype),
        "w_bcd": dense_init(ks[2], di, 2 * N + 1, dtype),     # B, C, dt
        "dt_bias": jnp.ones((di,), jnp.float32) * 0.5,
        "a_log": jnp.log(a),                                   # (di, N)
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[3], di, d_model, dtype),
    }


def _conv_causal(x, w):
    """Depthwise causal conv. x: (B, T, di); w: (W, di)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xp[:, i:i + x.shape[1]] * w[i][None, None, :]
    return out


def _ssm_inputs(params, u):
    """Common gating/projection math. u: (B, T, di) post-conv.

    Returns (dA (B,T,di,N) decay, dBx (B,T,di,N) input, C (B,T,N))."""
    N = (params["w_bcd"].shape[1] - 1) // 2
    bcd = u @ params["w_bcd"].astype(u.dtype)
    B_t = bcd[..., :N].astype(jnp.float32)                 # (B,T,N)
    C_t = bcd[..., N:2 * N].astype(jnp.float32)
    dt = jax.nn.softplus(bcd[..., -1].astype(jnp.float32)
                         + params["dt_bias"].mean())        # (B,T)
    A = -jnp.exp(params["a_log"])                           # (di, N)
    dA = jnp.exp(dt[..., None, None] * A[None, None])       # (B,T,di,N)
    dBx = (dt[..., None] * u.astype(jnp.float32))[..., None] \
        * B_t[..., None, :]                                 # (B,T,di,N)
    return dA, dBx, C_t


def mamba_forward(params, x, *, cfg):
    """Full-sequence forward. x: (B, T, D) -> (B, T, D).

    Chunked: sequential scan over T/chunk chunks; inside a chunk the
    recurrence h_t = dA_t h_{t-1} + dBx_t is unrolled via cumulative products
    in log space is avoided — we scan timesteps inside the chunk (cheap dense
    ops, static small trip count) to stay numerically exact.

    cfg.chunk_local=True computes projections/conv/gates INSIDE the chunk
    scan so no (B, T, di, N) tensor is ever materialised (peak activation
    memory drops by T/chunk); the baseline precomputes them for the whole
    sequence (the direct port of the reference implementation).
    """
    if getattr(cfg, "chunk_local", False):
        return _mamba_forward_chunk_local(params, x, cfg=cfg)
    B, T, D = x.shape
    di = cfg.d_inner_mult * D
    L = min(cfg.chunk, T)
    pad = (-T) % L
    xz = x @ params["w_in"].astype(x.dtype)
    u, z = jnp.split(xz, 2, axis=-1)
    u = _conv_causal(u, params["conv_w"].astype(u.dtype))
    u = jax.nn.silu(u)
    dA, dBx, C_t = _ssm_inputs(params, u)
    if pad:
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0), (0, 0)),
                     constant_values=1.0)
        dBx = jnp.pad(dBx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_t = jnp.pad(C_t, ((0, 0), (0, pad), (0, 0)))
    nC = (T + pad) // L

    def chunk_body(h, inp):
        dA_c, dBx_c, C_c = inp          # (B, L, di, N), (B, L, N)
        ys = []
        for t in range(L):              # static unroll inside chunk
            h = dA_c[:, t] * h + dBx_c[:, t]
            ys.append(jnp.einsum("bdn,bn->bd", h, C_c[:, t]))
        return h, jnp.stack(ys, axis=1)  # (B, L, di)

    h0 = jnp.zeros((B, di, N_state(params)), jnp.float32)
    xs = (dA.reshape(B, nC, L, di, -1).swapaxes(0, 1),
          dBx.reshape(B, nC, L, di, -1).swapaxes(0, 1),
          C_t.reshape(B, nC, L, -1).swapaxes(0, 1))
    _, y = jax.lax.scan(chunk_body, h0, xs)
    y = y.swapaxes(0, 1).reshape(B, T + pad, di)[:, :T]
    y = y + u.astype(jnp.float32) * params["d_skip"][None, None]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["w_out"].astype(x.dtype)


def _mamba_forward_chunk_local(params, x, *, cfg):
    """Memory-optimised path: everything is computed per chunk inside the
    scan; the conv tail (W-1 tokens) is carried between chunks."""
    B, T, D = x.shape
    di = cfg.d_inner_mult * D
    N = N_state(params)
    W = params["conv_w"].shape[0]
    L = min(cfg.chunk, T)
    pad = (-T) % L
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0))) if pad else x
    nC = (T + pad) // L
    xs = xp.reshape(B, nC, L, D).swapaxes(0, 1)          # (nC, B, L, D)

    w_in = params["w_in"]
    conv_w = params["conv_w"]

    def chunk_body(carry, x_c):
        h, tail = carry                                   # tail: (B, W-1, di)
        xz = x_c @ w_in.astype(x_c.dtype)                 # (B, L, 2di)
        u, z = jnp.split(xz, 2, axis=-1)
        u_ext = jnp.concatenate([tail.astype(u.dtype), u], axis=1)
        conv = jnp.zeros_like(u)
        for i in range(W):
            conv = conv + u_ext[:, i:i + L] * conv_w[i][None, None].astype(
                u.dtype)
        uc = jax.nn.silu(conv)
        dA, dBx, C_t = _ssm_inputs(params, uc)
        ys = []
        for t in range(L):
            h = dA[:, t] * h + dBx[:, t]
            ys.append(jnp.einsum("bdn,bn->bd", h, C_t[:, t]))
        y = jnp.stack(ys, axis=1)                         # (B, L, di) f32
        y = y + uc.astype(jnp.float32) * params["d_skip"][None, None]
        y = y.astype(x_c.dtype) * jax.nn.silu(z)
        out = y @ params["w_out"].astype(x_c.dtype)       # (B, L, D)
        new_tail = u_ext[:, L:L + W - 1]
        return (h, new_tail.astype(jnp.float32)), out

    h0 = jnp.zeros((B, di, N), jnp.float32)
    tail0 = jnp.zeros((B, W - 1, di), jnp.float32)
    _, ys = jax.lax.scan(chunk_body, (h0, tail0), xs)
    out = ys.swapaxes(0, 1).reshape(B, T + pad, D)
    return out[:, :T]


def N_state(params) -> int:
    return params["a_log"].shape[1]


class MambaState(NamedTuple):
    conv: jax.Array  # (B, W-1, di)
    h: jax.Array     # (B, di, N)


def mamba_init_state(params, batch: int, dtype=jnp.float32) -> MambaState:
    W, di = params["conv_w"].shape
    N = N_state(params)
    return MambaState(conv=jnp.zeros((batch, W - 1, di), dtype),
                      h=jnp.zeros((batch, di, N), jnp.float32))


def mamba_step(params, x, state: MambaState, *, cfg):
    """Single-token decode. x: (B, 1, D). Returns (y (B,1,D), new_state)."""
    B = x.shape[0]
    xz = x @ params["w_in"].astype(x.dtype)
    u, z = jnp.split(xz, 2, axis=-1)                    # (B, 1, di)
    conv_in = jnp.concatenate([state.conv, u.astype(state.conv.dtype)], axis=1)
    w = params["conv_w"].astype(jnp.float32)
    u_c = jnp.einsum("bwd,wd->bd", conv_in.astype(jnp.float32), w)[:, None]
    u_c = jax.nn.silu(u_c)
    dA, dBx, C_t = _ssm_inputs(params, u_c)
    h = dA[:, 0] * state.h + dBx[:, 0]
    y = jnp.einsum("bdn,bn->bd", h, C_t[:, 0])[:, None]
    y = y + u_c.astype(jnp.float32) * params["d_skip"][None, None]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    new_state = MambaState(conv=conv_in[:, 1:], h=h)
    return y @ params["w_out"].astype(x.dtype), new_state


def mamba_prefill_state(params, x, *, cfg) -> MambaState:
    """Exact post-sequence state (conv tail + ssm state) for decode handoff.

    x: (B, T, D) — the same input given to mamba_forward.
    """
    B, T, D = x.shape
    W = params["conv_w"].shape[0]
    xz = x @ params["w_in"].astype(x.dtype)
    u, _ = jnp.split(xz, 2, axis=-1)
    tail = u[:, -(W - 1):]
    if T < W - 1:
        tail = jnp.pad(u, ((0, 0), (W - 1 - T, 0), (0, 0)))
    u_c = jax.nn.silu(_conv_causal(u, params["conv_w"].astype(u.dtype)))
    dA, dBx, _ = _ssm_inputs(params, u_c)

    def step(h, inp):
        da, dbx = inp
        return da * h + dbx, None

    h0 = jnp.zeros((B, u.shape[-1], N_state(params)), jnp.float32)
    h, _ = jax.lax.scan(step, h0, (dA.swapaxes(0, 1), dBx.swapaxes(0, 1)))
    return MambaState(conv=tail.astype(jnp.float32), h=h)


def mamba_ref(params, x, *, cfg):
    """Step-by-step oracle for tests (runs decode path over the sequence)."""
    B, T, D = x.shape
    state = mamba_init_state(params, B)
    ys = []
    for t in range(T):
        y, state = mamba_step(params, x[:, t:t + 1], state, cfg=cfg)
        ys.append(y)
    return jnp.concatenate(ys, axis=1)

"""xLSTM blocks: chunkwise-parallel mLSTM (matrix memory, exponential gating)
and sequential sLSTM (scalar memory, head-wise recurrence).

mLSTM chunkwise form (per head, stabilised):
  log-forget lf_t = logsigmoid(f~_t), log-input li_t = i~_t
  b_t  = intra-chunk cumsum(lf);  a_s = li_s - b_s
  A_t  = max(m0, cummax_{s<=t} a_s)          (running stabiliser, m0 = carry)
  W[t,s] = exp(a_s - A_t)  (s<=t)            (intra-chunk weights)
  inter_t = exp(m0 - A_t)                    (carried-state coefficient)
  m_t = b_t + A_t                            (absolute stabiliser)
  num_t = sum_s W[t,s] (q_t.k_s/sqrt(d)) v_s + inter_t (q_t @ C0_hat)
  n_t  = sum_s W[t,s] k_s + inter_t n0_hat
  h_t  = num_t / max(|q_t.n_t|, exp(-m_t))   (exp arg clipped at 80)
carry:  C_hat' = sum_s exp(a_s - A_L) k_s v_s^T + exp(m0 - A_L) C_hat0
        n_hat' = sum_s exp(a_s - A_L) k_s    + exp(m0 - A_L) n_hat0
        m'     = b_L + A_L
A sequential single-step rule (used for decode and as the test oracle) applies
the same update one token at a time.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init

_CLIP = 80.0


def init_mlstm(key, d_model: int, n_heads: int, dtype) -> dict:
    ks = jax.random.split(key, 7)
    D = d_model
    return {
        "wq": dense_init(ks[0], D, D, dtype),
        "wk": dense_init(ks[1], D, D, dtype),
        "wv": dense_init(ks[2], D, D, dtype),
        "wog": dense_init(ks[3], D, D, dtype),
        "wo": dense_init(ks[4], D, D, dtype),
        "w_ig": dense_init(ks[5], D, n_heads, jnp.float32, scale=0.01),
        "w_fg": dense_init(ks[6], D, n_heads, jnp.float32, scale=0.01),
        "b_fg": jnp.full((n_heads,), 3.0, jnp.float32),  # open forget gates
        "b_ig": jnp.zeros((n_heads,), jnp.float32),
    }


class MLSTMState(NamedTuple):
    c: jax.Array  # (B, H, d, d)  stabilised matrix memory
    n: jax.Array  # (B, H, d)
    m: jax.Array  # (B, H)


def mlstm_init_state(batch: int, n_heads: int, head_dim: int) -> MLSTMState:
    return MLSTMState(
        c=jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
        n=jnp.zeros((batch, n_heads, head_dim), jnp.float32),
        m=jnp.full((batch, n_heads), -1e30, jnp.float32))


def _qkv_gates(params, x, n_heads: int):
    B, T, D = x.shape
    hd = D // n_heads
    q = (x @ params["wq"].astype(x.dtype)).reshape(B, T, n_heads, hd)
    k = (x @ params["wk"].astype(x.dtype)).reshape(B, T, n_heads, hd)
    v = (x @ params["wv"].astype(x.dtype)).reshape(B, T, n_heads, hd)
    x32 = x.astype(jnp.float32)
    li = x32 @ params["w_ig"] + params["b_ig"]            # (B,T,H)
    lf = jax.nn.log_sigmoid(x32 @ params["w_fg"] + params["b_fg"])
    og = jax.nn.sigmoid(x @ params["wog"].astype(x.dtype))  # (B,T,D)
    return q, k, v, li, lf, og


def mlstm_forward(params, x, *, n_heads: int, chunk: int = 128):
    """Full-sequence chunkwise mLSTM. x: (B,T,D) -> (B,T,D)."""
    B, T, D = x.shape
    hd = D // n_heads
    scale = 1.0 / np.sqrt(hd)
    q, k, v, li, lf, og = _qkv_gates(params, x, n_heads)
    L = min(chunk, T)
    pad = (-T) % L
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for t in (q, k, v))
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    nC = Tp // L

    def to_chunks(t):  # (B, Tp, ...) -> (nC, B, L, ...)
        return t.reshape((B, nC, L) + t.shape[2:]).swapaxes(0, 1)

    xs = tuple(map(to_chunks, (q, k, v, li, lf)))
    state = mlstm_init_state(B, n_heads, hd)

    def body(carry, inp):
        c0, n0, m0 = carry
        qc, kc, vc, lic, lfc = inp
        qf = qc.astype(jnp.float32) * scale
        kf, vf = kc.astype(jnp.float32), vc.astype(jnp.float32)
        b = jnp.cumsum(lfc, axis=1)                       # (B,L,H)
        a = lic - b
        A = jnp.maximum(m0[:, None], jax.lax.cummax(a, axis=1))  # (B,L,H)
        W = jnp.exp(jnp.clip(a[:, None, :] - A[:, :, None], -_CLIP, 0.0))
        # W: (B, t, s, H); causal mask s<=t
        tri = jnp.tril(jnp.ones((L, L), bool))
        W = jnp.where(tri[None, :, :, None], W, 0.0)
        scores = jnp.einsum("bthd,bshd->btsh", qf, kf)
        SW = scores * W
        num = jnp.einsum("btsh,bshd->bthd", SW, vf)
        inter = jnp.exp(jnp.clip(m0[:, None] - A, -_CLIP, 0.0))  # (B,L,H)
        num = num + inter[..., None] * jnp.einsum("bthd,bhde->bthe", qf, c0)
        n_t = jnp.einsum("btsh,bshd->bthd", W, kf)
        n_t = n_t + inter[..., None] * n0[:, None]
        m_t = b + A
        qn = jnp.abs(jnp.einsum("bthd,bthd->bth", qf, n_t))
        denom = jnp.maximum(qn, jnp.exp(jnp.clip(-m_t, None, _CLIP)))
        h = num / denom[..., None]
        # carry update at chunk end
        AL = A[:, -1]
        wk_coef = jnp.exp(jnp.clip(a - AL[:, None], -_CLIP, 0.0))
        wk_coef = wk_coef  # (B,L,H)
        c_new = jnp.einsum("bshd,bshe,bsh->bhde", kf, vf, wk_coef)
        i_coef = jnp.exp(jnp.clip(m0 - AL, -_CLIP, 0.0))
        c_new = c_new + i_coef[..., None, None] * c0
        n_new = jnp.einsum("bshd,bsh->bhd", kf, wk_coef) + i_coef[..., None] * n0
        m_new = b[:, -1] + AL
        return (c_new, n_new, m_new), h

    _, hs = jax.lax.scan(body, tuple(state), xs)
    h = hs.swapaxes(0, 1).reshape(B, Tp, D)[:, :T]
    h = h.astype(x.dtype) * og
    return h @ params["wo"].astype(x.dtype)


def mlstm_step(params, x, state: MLSTMState, *, n_heads: int):
    """Single-token decode. x: (B,1,D)."""
    B, _, D = x.shape
    hd = D // n_heads
    scale = 1.0 / np.sqrt(hd)
    q, k, v, li, lf, og = _qkv_gates(params, x, n_heads)
    qf = q[:, 0].astype(jnp.float32) * scale              # (B,H,d)
    kf, vf = k[:, 0].astype(jnp.float32), v[:, 0].astype(jnp.float32)
    li, lf = li[:, 0], lf[:, 0]                           # (B,H)
    m_new = jnp.maximum(lf + state.m, li)
    i_c = jnp.exp(jnp.clip(li - m_new, -_CLIP, 0.0))
    f_c = jnp.exp(jnp.clip(lf + state.m - m_new, -_CLIP, 0.0))
    c = f_c[..., None, None] * state.c \
        + i_c[..., None, None] * jnp.einsum("bhd,bhe->bhde", kf, vf)
    n = f_c[..., None] * state.n + i_c[..., None] * kf
    qn = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n))
    denom = jnp.maximum(qn, jnp.exp(jnp.clip(-m_new, None, _CLIP)))
    h = jnp.einsum("bhd,bhde->bhe", qf, c) / denom[..., None]
    h = h.reshape(B, 1, D).astype(x.dtype) * og
    return h @ params["wo"].astype(x.dtype), MLSTMState(c, n, m_new)


def mlstm_ref(params, x, *, n_heads: int):
    """Sequential oracle for tests."""
    B, T, D = x.shape
    state = mlstm_init_state(B, n_heads, D // n_heads)
    ys = []
    for t in range(T):
        y, state = mlstm_step(params, x[:, t:t + 1], state, n_heads=n_heads)
        ys.append(y)
    return jnp.concatenate(ys, axis=1)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, d_model: int, n_heads: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    hd = d_model // n_heads
    r = (jax.random.normal(ks[1], (4, n_heads, hd, hd), jnp.float32)
         / np.sqrt(hd))
    return {
        "w": dense_init(ks[0], d_model, 4 * d_model, dtype),  # i,f,z,o
        "r": r.astype(dtype),
        "b": jnp.concatenate([jnp.zeros((d_model,)), jnp.full((d_model,), 3.0),
                              jnp.zeros((2 * d_model,))]).astype(jnp.float32),
        "wo": dense_init(ks[2], d_model, d_model, dtype),
    }


class SLSTMState(NamedTuple):
    c: jax.Array  # (B, H, d)
    n: jax.Array
    m: jax.Array  # (B, H, d)
    h: jax.Array  # (B, H, d)


def slstm_init_state(batch: int, n_heads: int, head_dim: int) -> SLSTMState:
    z = jnp.zeros((batch, n_heads, head_dim), jnp.float32)
    return SLSTMState(c=z, n=z, m=z - 1e30, h=z)


def _slstm_cell(params, x_t, st: SLSTMState, n_heads: int):
    """x_t: (B, D)."""
    B, D = x_t.shape
    hd = D // n_heads
    wx = (x_t @ params["w"].astype(x_t.dtype)).astype(jnp.float32) \
        + params["b"]
    wx = wx.reshape(B, 4, n_heads, hd)
    rh = jnp.einsum("bhd,ghde->bghe", st.h, params["r"].astype(jnp.float32))
    it, ft, zt, ot = [wx[:, g] + rh[:, g] for g in range(4)]
    m_new = jnp.maximum(ft + st.m, it)
    i_c = jnp.exp(jnp.clip(it - m_new, -_CLIP, 0.0))
    f_c = jnp.exp(jnp.clip(ft + st.m - m_new, -_CLIP, 0.0))
    c = f_c * st.c + i_c * jnp.tanh(zt)
    n = jnp.maximum(f_c * st.n + i_c, 1e-6)
    h = jax.nn.sigmoid(ot) * c / n
    return SLSTMState(c=c, n=n, m=m_new, h=h)


def slstm_forward(params, x, *, n_heads: int):
    """x: (B,T,D) -> (B,T,D) via sequential scan over time."""
    B, T, D = x.shape
    st0 = slstm_init_state(B, n_heads, D // n_heads)

    def body(st, x_t):
        st = _slstm_cell(params, x_t, st, n_heads)
        return st, st.h

    _, hs = jax.lax.scan(body, st0, x.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).reshape(B, T, D).astype(x.dtype)
    return h @ params["wo"].astype(x.dtype)


def slstm_step(params, x, st: SLSTMState, *, n_heads: int):
    """x: (B,1,D)."""
    B, _, D = x.shape
    st = _slstm_cell(params, x[:, 0], st, n_heads)
    h = st.h.reshape(B, 1, D).astype(x.dtype)
    return h @ params["wo"].astype(x.dtype), st


# ---------------------------------------------------------------------------
# sLSTM with locally-accumulated recurrent-weight gradients
# ---------------------------------------------------------------------------
#
# Under plain GSPMD, the backward of the time scan emits a partial-sum
# all-reduce for dR/dW at EVERY timestep (the psum cannot hoist through the
# while loop) — ~50k collectives per step for xlstm-1.3b train
# (EXPERIMENTS.md §Perf). Here the whole recurrence runs inside shard_map:
# batch rows are local, the backward scan accumulates dparams locally
# (per-step jax.vjp of the local cell — correctness by construction), and
# ONE psum at the end reduces across the batch shards.


def slstm_forward_sharded(params, x, *, n_heads: int, mesh, batch_axes):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis_names = tuple(a for a in batch_axes)
    rwb = {"w": params["w"], "r": params["r"], "b": params["b"]}

    def local(rwb_, x_loc):
        return _slstm_scan_lg(rwb_, x_loc, n_heads, axis_names)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(), P(batch_axes, None, None)),
                   out_specs=P(batch_axes, None, None), check_rep=False)
    h = fn(rwb, x)
    return h @ params["wo"].astype(x.dtype)


def _make_cell(n_heads):
    def cell(rwb, x_t, st_tuple):
        st = SLSTMState(*st_tuple)
        st2 = _slstm_cell(rwb, x_t, st, n_heads)
        return (st2.c, st2.n, st2.m, st2.h)
    return cell


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _slstm_scan_lg(rwb, x, n_heads, axis_names):
    out, _ = _slstm_scan_fwd_impl(rwb, x, n_heads)
    return out


def _slstm_scan_fwd_impl(rwb, x, n_heads):
    B, T, D = x.shape
    st0 = slstm_init_state(B, n_heads, D // n_heads)
    cell = _make_cell(n_heads)

    def body(st, x_t):
        st2 = cell(rwb, x_t, st)
        return st2, st2

    _, traj = jax.lax.scan(body, tuple(st0), x.swapaxes(0, 1))
    h = traj[3].swapaxes(0, 1).reshape(B, T, D).astype(x.dtype)
    return h, traj


def _slstm_lg_fwd(rwb, x, n_heads, axis_names):
    out, traj = _slstm_scan_fwd_impl(rwb, x, n_heads)
    return out, (rwb, x, traj)


def _slstm_lg_bwd(n_heads, axis_names, res, g):
    rwb, x, traj = res
    B, T, D = x.shape
    st0 = tuple(slstm_init_state(B, n_heads, D // n_heads))
    cell = _make_cell(n_heads)
    g_h = g.reshape(B, T, n_heads, D // n_heads).astype(jnp.float32) \
        .swapaxes(0, 1)                                   # (T, B, H, dh)
    xs_T = x.swapaxes(0, 1)
    # previous state per step: shift trajectory right by one
    prev = jax.tree_util.tree_map(
        lambda tr, s0: jnp.concatenate([s0[None], tr[:-1]], axis=0),
        traj, st0)

    d_rwb0 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), rwb)
    dst0 = tuple(jnp.zeros((B, n_heads, D // n_heads), jnp.float32)
                 for _ in range(4))

    def body(carry, inp):
        d_rwb, dst = carry
        x_t, st_prev, gh_t = inp
        _, pullback = jax.vjp(cell, rwb, x_t, st_prev)
        dout = (dst[0], dst[1], dst[2], dst[3] + gh_t)
        d_rwb_t, dx_t, dst_prev = pullback(dout)
        d_rwb = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(jnp.float32), d_rwb, d_rwb_t)
        return (d_rwb, tuple(d.astype(jnp.float32) for d in dst_prev)), dx_t

    (d_rwb, _), dx_T = jax.lax.scan(body, (d_rwb0, dst0),
                                    (xs_T, prev, g_h), reverse=True)
    # ONE cross-shard reduction instead of one per timestep
    d_rwb = jax.tree_util.tree_map(
        lambda a: jax.lax.psum(a, axis_names), d_rwb)
    d_rwb = jax.tree_util.tree_map(lambda a, p: a.astype(p.dtype),
                                   d_rwb, rwb)
    return d_rwb, dx_T.swapaxes(0, 1).astype(x.dtype)


_slstm_scan_lg.defvjp(_slstm_lg_fwd, _slstm_lg_bwd)

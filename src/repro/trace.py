"""Top-level re-exports of the request-tracing subsystem.

``repro.trace`` is the public face of :mod:`repro.serve.trace` —
per-request lifecycle tracing for the serving stack: bounded
:class:`Tracer` ring of :class:`Span` records (submit → cache_lookup /
coalesce → admit → queue_wait → encode → dispatch → device_execute →
complete | shed | drop | negative_drop, plus capacity-controller
actions), :class:`TraceReport` per-stage latency percentiles with
per-replica straggler attribution, an ASCII per-request timeline
(:func:`render_timeline`), and Chrome ``trace_event`` / JSONL
exporters. See that module's docstring for the full story; enable in a
serving stack with ``ServeConfig(trace=True)`` (default off — the
disabled stack is bit-identical to the untraced one).
"""
from repro.serve.trace import (LIFECYCLE_STAGES, ReplicaTraceStats, Span,
                               TraceConfig, TraceReport, Tracer,
                               chrome_events, render_timeline)

__all__ = [
    "LIFECYCLE_STAGES", "ReplicaTraceStats", "Span", "TraceConfig",
    "TraceReport", "Tracer", "chrome_events", "render_timeline",
]

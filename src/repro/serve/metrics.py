"""Serving metrics: per-request latency breakdown + device utilisation.

The paper's key end-to-end signal (§5–6) is *imbalance*: the accelerator
only pays off when the host can keep it fed, so the numbers that matter are
(a) where each request's latency goes — queue wait vs host encode vs device
execution vs drain — and (b) what fraction of the run the device sat idle.
``MetricsCollector`` is thread-safe and shared by the synchronous baseline,
the pipelined executor, and the live async scheduler, so all three report
comparable numbers.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass
class RequestTrace:
    rid: int
    arrival: Optional[float] = None
    admitted: Optional[float] = None
    encode_start: Optional[float] = None
    encode_end: Optional[float] = None
    device_start: Optional[float] = None
    device_end: Optional[float] = None
    completed: Optional[float] = None
    rejected: bool = False
    shed: bool = False
    replica: Optional[int] = None     # which replica executed it
    cache_hit: bool = False           # served from the result cache
    coalesced: bool = False           # follower of an in-flight leader

    def _ms(self, a: Optional[float], b: Optional[float]) -> Optional[float]:
        return (b - a) * 1e3 if a is not None and b is not None else None

    @property
    def queue_wait_ms(self):
        return self._ms(self.arrival if self.arrival is not None
                        else self.admitted, self.encode_start)

    @property
    def encode_ms(self):
        return self._ms(self.encode_start, self.encode_end)

    @property
    def device_ms(self):
        return self._ms(self.device_start, self.device_end)

    @property
    def drain_ms(self):
        return self._ms(self.device_end, self.completed)

    @property
    def total_ms(self):
        return self._ms(self.arrival if self.arrival is not None
                        else self.admitted, self.completed)


@dataclass
class LatencyStats:
    n: int
    mean_ms: float
    p50_ms: float
    p95_ms: float
    p99_ms: float
    max_ms: float

    @classmethod
    def of(cls, values_ms: List[float]) -> "LatencyStats":
        if not values_ms:
            return cls(0, 0.0, 0.0, 0.0, 0.0, 0.0)
        v = np.asarray(values_ms, np.float64)
        return cls(n=len(values_ms), mean_ms=float(v.mean()),
                   p50_ms=float(np.percentile(v, 50)),
                   p95_ms=float(np.percentile(v, 95)),
                   p99_ms=float(np.percentile(v, 99)),
                   max_ms=float(v.max()))

    def as_dict(self) -> Dict[str, float]:
        return {"n": self.n, "mean_ms": self.mean_ms, "p50_ms": self.p50_ms,
                "p95_ms": self.p95_ms, "p99_ms": self.p99_ms,
                "max_ms": self.max_ms}


def _merged_span(intervals: List[Tuple[float, float]]) -> float:
    """Total covered time of possibly-overlapping intervals (overlap happens
    with multi-device round-robin execution)."""
    if not intervals:
        return 0.0
    out = 0.0
    cur_a, cur_b = None, None
    for a, b in sorted(intervals):
        if cur_b is None or a > cur_b:
            if cur_b is not None:
                out += cur_b - cur_a
            cur_a, cur_b = a, b
        else:
            cur_b = max(cur_b, b)
    out += cur_b - cur_a
    return out


@dataclass(frozen=True)
class SignalSnapshot:
    """Cumulative totals at one instant — the capacity subsystem diffs two
    of these to get window rates (``CapacitySignals.between``). Totals
    only; no derived rates, so diffing is exact and lock-free."""
    t: float
    n_arrivals: int
    n_completions: int
    n_rejected: int
    n_shed: int
    n_encoded_batches: int
    encode_busy_s: float          # serial host-prepare time (batcher thread)
    device_busy_s: float          # summed across replicas (not merged)
    cache_hits: int
    cache_misses: int
    cache_coalesced: int


@dataclass
class ReplicaStats:
    """Per-replica serving statistics (the sharded-serving view: which
    replicas did the work, how idle each sat, how deep its pipeline ran)."""
    replica: int
    n_batches: int
    n_requests: int
    busy_s: float
    idle_fraction: float
    max_pipeline_depth: int       # prepared batches queued in its handoff
    max_outstanding_work: int     # routing's work-unit view at dispatch
    cache_hits: int = 0           # hits served from results this replica made
    cache_hit_rate: float = 0.0   # hits / (hits + requests it executed)

    def as_dict(self) -> Dict[str, object]:
        return {"replica": self.replica, "n_batches": self.n_batches,
                "n_requests": self.n_requests, "busy_s": self.busy_s,
                "idle_fraction": self.idle_fraction,
                "max_pipeline_depth": self.max_pipeline_depth,
                "max_outstanding_work": self.max_outstanding_work,
                "cache_hits": self.cache_hits,
                "cache_hit_rate": self.cache_hit_rate}


@dataclass
class RunReport:
    n_requests: int
    n_completed: int
    n_rejected: int
    n_shed: int
    offered_qps: Optional[float]
    achieved_qps: float
    span_s: float
    device_busy_s: float
    device_idle_fraction: float
    max_queue_depth: int
    batch_sizes: List[int]
    breakdown: Dict[str, LatencyStats]
    per_replica: Dict[int, ReplicaStats] = field(default_factory=dict)
    routing: Dict[str, int] = field(default_factory=dict)
    # result-cache counters (empty dict when no cache was configured):
    # hits/misses/coalesced/evictions/stale/follower_drops, bytes_resident,
    # entries, hit_rate = (hits+coalesced)/(hits+misses+coalesced), plus
    # negative_hits/negative_stores and leader_promotions when those
    # features fire
    cache: Dict[str, object] = field(default_factory=dict)
    # capacity-controller view (empty dict when capacity=None): diagnosis,
    # diagnosis history, controller actions, final knob values,
    # mean_active_replicas
    capacity: Dict[str, object] = field(default_factory=dict)

    @property
    def affinity_hits(self) -> int:
        """Batches hit_aware routed to their content's owning replica."""
        return self.routing.get("affinity_hit", 0)

    @property
    def affinity_spills(self) -> int:
        """Batches whose owner preference was overridden (straggler or
        outstanding-work gap) and whose keys were re-homed."""
        return self.routing.get("affinity_spill", 0)

    def as_dict(self) -> Dict[str, object]:
        return {
            "n_requests": self.n_requests,
            "n_completed": self.n_completed,
            "n_rejected": self.n_rejected,
            "n_shed": self.n_shed,
            "offered_qps": self.offered_qps,
            "achieved_qps": self.achieved_qps,
            "span_s": self.span_s,
            "device_busy_s": self.device_busy_s,
            "device_idle_fraction": self.device_idle_fraction,
            "max_queue_depth": self.max_queue_depth,
            "mean_batch": float(np.mean(self.batch_sizes))
            if self.batch_sizes else 0.0,
            "breakdown": {k: v.as_dict() for k, v in self.breakdown.items()},
            "per_replica": {k: v.as_dict()
                            for k, v in sorted(self.per_replica.items())},
            "routing": dict(self.routing),
            "cache": dict(self.cache),
            "capacity": dict(self.capacity),
        }

    def summary(self) -> str:
        t = self.breakdown.get("total")
        return (f"{self.n_completed}/{self.n_requests} done "
                f"({self.n_rejected} rejected, {self.n_shed} shed) "
                f"achieved {self.achieved_qps:.1f} q/s"
                + (f" of offered {self.offered_qps:.1f}"
                   if self.offered_qps else "")
                + f", device idle {self.device_idle_fraction * 100:.0f}%"
                + (f" over {len(self.per_replica)} replicas"
                   if len(self.per_replica) > 1 else "")
                + (f", cache hit {self.cache['hit_rate'] * 100:.0f}%"
                   if self.cache else "")
                + (f", affinity {self.affinity_hits} hit"
                   f"/{self.affinity_spills} spill"
                   if self.affinity_hits or self.affinity_spills else "")
                + (f", diagnosed {self.capacity['diagnosis']}"
                   if self.capacity.get("diagnosis") else "")
                + (f", p50/p95/p99 {t.p50_ms:.0f}/{t.p95_ms:.0f}/"
                   f"{t.p99_ms:.0f} ms" if t and t.n else ""))


class MetricsCollector:
    """Thread-safe event sink for the serving pipeline."""

    def __init__(self):
        self._lock = threading.Lock()
        self._traces: Dict[int, RequestTrace] = {}
        self._device_busy: List[Tuple[float, float]] = []
        self._batch_sizes: List[int] = []
        self.max_queue_depth = 0
        # sharded-serving state: per-replica busy intervals / load counters
        # and routing-decision counts (reason -> n)
        self._replica_busy: Dict[int, List[Tuple[float, float]]] = {}
        self._replica_batches: Dict[int, int] = {}
        self._replica_requests: Dict[int, int] = {}
        self._replica_max_depth: Dict[int, int] = {}
        self._replica_max_work: Dict[int, int] = {}
        self._routing: Dict[str, int] = {}
        # result-cache state: event counters, resident-size snapshot, and
        # per-replica hit attribution (hits credited to the replica that
        # produced the cached entry)
        self._cache_counts: Dict[str, int] = {}
        self._cache_bytes = 0
        self._cache_entries = 0
        self._cache_seen = False
        self._replica_cache_hits: Dict[int, int] = {}
        # capacity-subsystem state: cumulative totals for window diffing
        # (SignalSnapshot) + the controller-action log
        self._n_arrivals = 0
        self._n_completions = 0
        self._n_rejected = 0
        self._n_shed = 0
        self._n_encoded_batches = 0
        self._encode_busy_s = 0.0
        self._device_busy_total_s = 0.0
        self._capacity_log: List[Dict[str, object]] = []

    def _t(self, rid: int) -> RequestTrace:
        tr = self._traces.get(rid)
        if tr is None:
            tr = self._traces[rid] = RequestTrace(rid)
        return tr

    # -- event hooks (called from submitter / batcher / device threads) ------
    def on_arrival(self, rid: int, t: float):
        with self._lock:
            self._t(rid).arrival = t
            self._n_arrivals += 1

    def on_admit(self, rid: int, t: float):
        with self._lock:
            tr = self._t(rid)
            tr.admitted = t
            if tr.arrival is None:
                tr.arrival = t

    def on_reject(self, rid: int, t: float):
        with self._lock:
            tr = self._t(rid)
            tr.rejected = True
            self._n_rejected += 1
            if tr.arrival is None:
                tr.arrival = t

    def on_shed(self, rid: int, t: float):
        with self._lock:
            self._t(rid).shed = True
            self._n_shed += 1

    def on_encode(self, rids: List[int], t0: float, t1: float):
        with self._lock:
            self._n_encoded_batches += 1
            self._encode_busy_s += max(0.0, t1 - t0)
            for rid in rids:
                tr = self._t(rid)
                tr.encode_start, tr.encode_end = t0, t1
                if tr.arrival is None:
                    tr.arrival = t0

    def on_device(self, rids: List[int], t0: float, t1: float,
                  replica: Optional[int] = None):
        with self._lock:
            self._device_busy.append((t0, t1))
            self._device_busy_total_s += max(0.0, t1 - t0)
            self._batch_sizes.append(len(rids))
            if replica is not None:
                self._replica_busy.setdefault(replica, []).append((t0, t1))
                self._replica_batches[replica] = \
                    self._replica_batches.get(replica, 0) + 1
                self._replica_requests[replica] = \
                    self._replica_requests.get(replica, 0) + len(rids)
            for rid in rids:
                tr = self._t(rid)
                tr.device_start, tr.device_end = t0, t1
                if replica is not None:
                    tr.replica = replica

    def on_complete(self, rids: List[int], t: float):
        with self._lock:
            self._n_completions += len(rids)
            for rid in rids:
                self._t(rid).completed = t

    # -- capacity-subsystem hooks -------------------------------------------
    def snapshot(self, now: float) -> SignalSnapshot:
        """Cumulative totals at ``now`` — the capacity controller diffs two
        of these into one sliding window of rates."""
        with self._lock:
            g = self._cache_counts.get
            return SignalSnapshot(
                t=now,
                n_arrivals=self._n_arrivals,
                n_completions=self._n_completions,
                n_rejected=self._n_rejected,
                n_shed=self._n_shed,
                n_encoded_batches=self._n_encoded_batches,
                encode_busy_s=self._encode_busy_s,
                device_busy_s=self._device_busy_total_s,
                cache_hits=g("hits", 0),
                cache_misses=g("misses", 0),
                cache_coalesced=g("coalesced", 0),
            )

    def on_capacity(self, entry: Dict[str, object]):
        """One controller action (as_dict of a ControllerAction)."""
        with self._lock:
            self._capacity_log.append(dict(entry))

    def capacity_actions(self) -> List[Dict[str, object]]:
        with self._lock:
            return [dict(e) for e in self._capacity_log]

    # -- result-cache events ---------------------------------------------------
    def on_cache(self, event: str, n: int = 1):
        """Generic cache counter bump (stale / evictions / follower_drops
        — forwarded by ResultCache/AsyncScheduler)."""
        with self._lock:
            self._cache_seen = True
            self._cache_counts[event] = self._cache_counts.get(event, 0) + n

    def on_cache_hit(self, rid: int, t: float,
                     replica: Optional[int] = None):
        """Request served straight from the result cache; ``replica`` is
        the replica that produced the cached entry (per-replica hit-rate
        attribution)."""
        with self._lock:
            self._cache_seen = True
            self._cache_counts["hits"] = self._cache_counts.get("hits", 0) + 1
            tr = self._t(rid)
            tr.cache_hit = True
            if tr.arrival is None:
                tr.arrival = t
            if replica is not None:
                self._replica_cache_hits[replica] = \
                    self._replica_cache_hits.get(replica, 0) + 1

    def on_cache_miss(self, rid: int):
        """Admitted leader: content not in cache, flows through the full
        pipeline (and fills the cache on completion)."""
        with self._lock:
            self._cache_seen = True
            self._cache_counts["misses"] = \
                self._cache_counts.get("misses", 0) + 1

    def on_coalesce(self, rid: int, leader_rid: int, t: float):
        """Follower attached to in-flight leader ``leader_rid``: costs no
        admission-queue space, no host encode, no device time."""
        with self._lock:
            self._cache_seen = True
            self._cache_counts["coalesced"] = \
                self._cache_counts.get("coalesced", 0) + 1
            tr = self._t(rid)
            tr.coalesced = True
            if tr.arrival is None:
                tr.arrival = t

    def note_cache_bytes(self, bytes_resident: int, entries: int):
        with self._lock:
            self._cache_seen = True
            self._cache_bytes = bytes_resident
            self._cache_entries = entries

    def replica_of(self, rid: int) -> Optional[int]:
        """Which replica executed ``rid`` (None if it never hit a
        device)."""
        with self._lock:
            tr = self._traces.get(rid)
            return tr.replica if tr is not None else None

    def arrival_of(self, rid: int) -> Optional[float]:
        """The arrival timestamp recorded for ``rid`` (None if unseen) —
        the tracer uses it so queue-wait spans start at the exact value
        the latency breakdown uses."""
        with self._lock:
            tr = self._traces.get(rid)
            return tr.arrival if tr is not None else None

    def note_queue_depth(self, depth: int):
        with self._lock:
            if depth > self.max_queue_depth:
                self.max_queue_depth = depth

    def note_replica_depth(self, replica: int, pipeline_depth: int,
                           outstanding_work: int):
        """Routing-time snapshot of one replica's pipeline: queued prepared
        batches and outstanding work units."""
        with self._lock:
            if pipeline_depth > self._replica_max_depth.get(replica, 0):
                self._replica_max_depth[replica] = pipeline_depth
            if outstanding_work > self._replica_max_work.get(replica, 0):
                self._replica_max_work[replica] = outstanding_work

    def on_route(self, replica: int, reason: str):
        """One routing decision: ``reason`` is the router's justification
        (single / sticky / least_loaded / tie_break / affinity_hit /
        affinity_spill)."""
        with self._lock:
            self._routing[reason] = self._routing.get(reason, 0) + 1
            # replicas that never execute (all work routed away) must still
            # appear in the per-replica report
            self._replica_batches.setdefault(replica, 0)
            self._replica_requests.setdefault(replica, 0)

    # -- aggregation ---------------------------------------------------------
    def report(self, *, offered_qps: Optional[float] = None) -> RunReport:
        with self._lock:
            traces = list(self._traces.values())
            busy = list(self._device_busy)
            batch_sizes = list(self._batch_sizes)
            max_depth = self.max_queue_depth
            replica_busy = {k: list(v) for k, v in self._replica_busy.items()}
            replica_batches = dict(self._replica_batches)
            replica_requests = dict(self._replica_requests)
            replica_max_depth = dict(self._replica_max_depth)
            replica_max_work = dict(self._replica_max_work)
            routing = dict(self._routing)
            cache_counts = dict(self._cache_counts)
            cache_bytes, cache_entries = self._cache_bytes, \
                self._cache_entries
            cache_seen = self._cache_seen
            replica_cache_hits = dict(self._replica_cache_hits)
            capacity_log = [dict(e) for e in self._capacity_log]
        done = [t for t in traces if t.completed is not None]
        starts = [t.arrival for t in traces if t.arrival is not None]
        ends = [t.completed for t in done]
        span = (max(ends) - min(starts)) if starts and ends else 0.0
        busy_s = _merged_span(busy)
        idle = 1.0 - busy_s / span if span > 0 else 0.0
        breakdown = {
            "queue_wait": LatencyStats.of(
                [t.queue_wait_ms for t in done
                 if t.queue_wait_ms is not None]),
            "encode": LatencyStats.of(
                [t.encode_ms for t in done if t.encode_ms is not None]),
            "device": LatencyStats.of(
                [t.device_ms for t in done if t.device_ms is not None]),
            "drain": LatencyStats.of(
                [t.drain_ms for t in done if t.drain_ms is not None]),
            "total": LatencyStats.of(
                [t.total_ms for t in done if t.total_ms is not None]),
        }
        per_replica = {}
        for k in sorted(set(replica_batches) | set(replica_busy)
                        | set(replica_cache_hits)):
            rb = _merged_span(replica_busy.get(k, []))
            ridle = 1.0 - rb / span if span > 0 else 0.0
            ch = replica_cache_hits.get(k, 0)
            served = ch + replica_requests.get(k, 0)
            per_replica[k] = ReplicaStats(
                replica=k,
                n_batches=replica_batches.get(k, 0),
                n_requests=replica_requests.get(k, 0),
                busy_s=rb,
                idle_fraction=max(0.0, min(1.0, ridle)),
                max_pipeline_depth=replica_max_depth.get(k, 0),
                max_outstanding_work=replica_max_work.get(k, 0),
                cache_hits=ch,
                cache_hit_rate=ch / served if served else 0.0,
            )
        cache: Dict[str, object] = {}
        if cache_seen:
            g = cache_counts.get
            tracked = g("hits", 0) + g("misses", 0) + g("coalesced", 0)
            cache = {
                "hits": g("hits", 0), "misses": g("misses", 0),
                "coalesced": g("coalesced", 0),
                "evictions": g("evictions", 0), "stale": g("stale", 0),
                "follower_drops": g("follower_drops", 0),
                "bytes_resident": cache_bytes, "entries": cache_entries,
                "hit_rate": (g("hits", 0) + g("coalesced", 0)) / tracked
                if tracked else 0.0,
            }
            for extra in ("negative_hits", "negative_stores",
                          "leader_promotions"):
                if g(extra, 0):
                    cache[extra] = g(extra, 0)
        capacity: Dict[str, object] = {}
        if capacity_log:
            capacity = {"actions": capacity_log}
        return RunReport(
            n_requests=len(traces),
            n_completed=len(done),
            n_rejected=sum(t.rejected for t in traces),
            n_shed=sum(t.shed for t in traces),
            offered_qps=offered_qps,
            achieved_qps=len(done) / span if span > 0 else 0.0,
            span_s=span,
            device_busy_s=busy_s,
            device_idle_fraction=max(0.0, min(1.0, idle)),
            max_queue_depth=max_depth,
            batch_sizes=batch_sizes,
            breakdown=breakdown,
            per_replica=per_replica,
            routing=routing,
            cache=cache,
            capacity=capacity,
        )

"""End-to-end request tracing: structured spans across the serving stack.

The paper's end-to-end analysis (§5–6) is precisely that aggregate
throughput hides *where* requests spend their time — a deployment can look
fine at the window level while every request queues behind a saturated
host prepare path. The metrics layer reports window aggregates
(:class:`~repro.serve.metrics.RunReport`); this module records the raw
per-request timeline those aggregates are computed from:

    submit -> cache_lookup/coalesce -> admit -> queue_wait -> encode
           -> dispatch(replica=r) -> device_execute
           -> complete | reject | shed | drop | negative_drop

plus ``controller`` events from the capacity subsystem, so batch-target
doubling and replica parking are visible on the same timeline as the
requests they affect.

Design rules:

- **Off by default, bit-identical off.** Every emission site in
  ``scheduler``/``cache``/``group``/``server``/``capacity`` is guarded by
  ``if tracer is not None``; with ``ServeConfig(trace=None)`` (the
  default) not a single extra call runs and the stack behaves exactly as
  it did without this module.
- **Bounded and thread-safe.** Spans land in a ring buffer
  (``TraceConfig.capacity`` entries, oldest evicted first) behind one
  lock; emission is an append, never an allocation-heavy aggregation.
  ``n_dropped`` says how much history the ring evicted.
- **Same clocks as metrics.** Emission sites reuse the *exact* timestamp
  values they hand to ``MetricsCollector`` (the worker's device t0/t1,
  the batcher's encode t0/t1, the submit-time arrival), so a
  :class:`TraceReport` derived from spans reconciles with the
  ``RunReport`` computed from the same run — tests assert it.

Exporters: Chrome ``trace_event`` JSON (load in ``chrome://tracing`` or
Perfetto — one lane per replica, async lanes for queue wait, instants
for lifecycle and controller events) and JSONL (one span per line).
"""
from __future__ import annotations

import json
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve.config import Coercible
from repro.serve.metrics import LatencyStats

# canonical stage names, in lifecycle order (exporters and reports keep
# this order; emission sites must not invent ad-hoc spellings)
LIFECYCLE_STAGES = (
    "submit", "cache_lookup", "coalesce", "admit", "queue_wait", "encode",
    "dispatch", "device_execute", "complete",
    "reject", "shed", "drop", "follower_drop", "negative_drop",
    "cache_store", "controller",
)


@dataclass
class TraceConfig(Coercible):
    """Tracing knobs (attach to ``ServeConfig.trace`` /
    ``SchedulerConfig.trace``; ``None`` keeps tracing fully off and the
    stack bit-identical to its untraced behavior).

    ``capacity`` — ring-buffer bound in spans; the oldest spans are
    evicted first once full (``TraceReport.n_dropped`` reports how many).
    """
    capacity: int = 65536


@dataclass
class Span:
    """One traced event. A *span* covers ``[t0, t1]``; a *mark* is a
    zero-duration span (``t1 == t0``). ``rid`` ties it to a request,
    ``replica`` to an engine replica; batch-level spans carry the batch's
    rids in ``meta["rids"]`` instead of a single ``rid``."""
    stage: str
    t0: float
    t1: float
    rid: Optional[int] = None
    replica: Optional[int] = None
    meta: Optional[dict] = None

    @property
    def duration_ms(self) -> float:
        return (self.t1 - self.t0) * 1e3

    @property
    def is_mark(self) -> bool:
        return self.t1 == self.t0

    def as_dict(self) -> Dict[str, object]:
        d: Dict[str, object] = {"stage": self.stage,
                                "t0": self.t0, "t1": self.t1}
        if self.rid is not None:
            d["rid"] = int(self.rid)
        if self.replica is not None:
            d["replica"] = int(self.replica)
        if self.meta:
            d["meta"] = {k: _json_safe(v) for k, v in self.meta.items()}
        return d


def _json_safe(v):
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    if isinstance(v, np.integer):
        return int(v)
    if isinstance(v, np.floating):
        return float(v)
    return v


class Tracer:
    """Thread-safe bounded span sink shared by every layer of one serving
    stack (``Server`` owns one; sessions, replica workers, the cache, and
    the capacity controller all emit into it)."""

    def __init__(self, config=None):
        self.cfg = TraceConfig.coerce(config) or TraceConfig()
        self._lock = threading.Lock()
        self._spans: "deque[Span]" = deque(maxlen=max(1, self.cfg.capacity))
        self.n_emitted = 0

    def span(self, stage: str, t0: float, t1: float, *,
             rid: Optional[int] = None, replica: Optional[int] = None,
             **meta) -> Span:
        """Record a duration span (``mark`` for zero-duration events)."""
        s = Span(stage, t0, t1, rid=rid, replica=replica,
                 meta=meta or None)
        with self._lock:
            self._spans.append(s)
            self.n_emitted += 1
        return s

    def mark(self, stage: str, t: float, *, rid: Optional[int] = None,
             replica: Optional[int] = None, **meta) -> Span:
        """Record an instantaneous event."""
        return self.span(stage, t, t, rid=rid, replica=replica, **meta)

    def spans(self) -> List[Span]:
        """Snapshot of the ring's contents, oldest first."""
        with self._lock:
            return list(self._spans)

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)

    @property
    def n_dropped(self) -> int:
        """Spans evicted by the ring bound so far."""
        with self._lock:
            return self.n_emitted - len(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self.n_emitted = 0

    # -- derived views --------------------------------------------------------
    def report(self) -> "TraceReport":
        spans = self.spans()
        return TraceReport.from_spans(spans, n_dropped=self.n_dropped)

    def timeline(self, rid: int) -> str:
        return render_timeline(self.spans(), rid)

    def to_chrome_events(self) -> List[Dict[str, object]]:
        return chrome_events(self.spans())

    def export_chrome(self, path: str) -> str:
        """Write a Chrome ``trace_event`` JSON file (open in
        ``chrome://tracing`` / Perfetto). Returns ``path``."""
        payload = {"traceEvents": self.to_chrome_events(),
                   "displayTimeUnit": "ms"}
        with open(path, "w") as f:
            json.dump(payload, f)
        return path

    def export_jsonl(self, path: str) -> str:
        """Write one span per line as JSON. Returns ``path``."""
        with open(path, "w") as f:
            for s in self.spans():
                f.write(json.dumps(s.as_dict()) + "\n")
        return path


# ---------------------------------------------------------------------------
# Report: per-stage percentiles + per-replica straggler attribution
# ---------------------------------------------------------------------------


@dataclass
class ReplicaTraceStats:
    """Per-replica view derived from ``dispatch``/``device_execute``
    spans — the straggler-attribution counterpart of
    :class:`~repro.serve.metrics.ReplicaStats`."""
    replica: int
    n_dispatches: int
    n_batches: int
    n_requests: int
    busy_s: float
    mean_batch_ms: float
    p95_batch_ms: float
    slowdown: float     # mean batch time / fleet mean (1.0 = typical,
                        # >1 = straggler)

    def as_dict(self) -> Dict[str, object]:
        return {"replica": self.replica,
                "n_dispatches": self.n_dispatches,
                "n_batches": self.n_batches,
                "n_requests": self.n_requests, "busy_s": self.busy_s,
                "mean_batch_ms": self.mean_batch_ms,
                "p95_batch_ms": self.p95_batch_ms,
                "slowdown": self.slowdown}


# stages whose per-request durations the report aggregates (and that can
# be compared against RunReport.breakdown's same-named entries)
_DURATION_STAGES = ("queue_wait", "encode", "device_execute", "total")


@dataclass
class TraceReport:
    """Aggregates derived purely from raw spans: per-stage latency
    percentiles over *completed* requests (comparable to
    ``RunReport.breakdown``), lifecycle/outcome counts, and per-replica
    straggler attribution."""
    stages: Dict[str, LatencyStats]
    counts: Dict[str, int]
    per_replica: Dict[int, ReplicaTraceStats] = field(default_factory=dict)
    n_spans: int = 0
    n_dropped: int = 0
    span_s: float = 0.0

    @classmethod
    def from_spans(cls, spans: Sequence[Span], *,
                   n_dropped: int = 0) -> "TraceReport":
        counts: Dict[str, int] = {}
        submit_t: Dict[int, float] = {}
        complete_t: Dict[int, float] = {}
        queue_wait: Dict[int, float] = {}
        encode: Dict[int, float] = {}
        device: Dict[int, float] = {}
        disp_by_replica: Dict[int, int] = {}
        dev_spans: Dict[int, List[Span]] = {}
        for s in spans:
            counts[s.stage] = counts.get(s.stage, 0) + 1
            if s.stage == "cache_lookup" and s.meta:
                out = s.meta.get("outcome")
                if out:
                    k = f"cache_{out}"
                    counts[k] = counts.get(k, 0) + 1
            if s.stage == "dispatch" and s.meta:
                # routing-reason breakdown (affinity_hit/affinity_spill/
                # least_loaded/...) — reconciles with RunReport.routing
                reason = s.meta.get("reason")
                if reason:
                    k = f"dispatch_{reason}"
                    counts[k] = counts.get(k, 0) + 1
            rids = (s.meta or {}).get("rids")
            if s.stage == "submit" and s.rid is not None:
                submit_t[s.rid] = s.t0
            elif s.stage == "complete" and s.rid is not None:
                complete_t[s.rid] = s.t0
            elif s.stage == "queue_wait" and s.rid is not None:
                queue_wait[s.rid] = s.duration_ms
            elif s.stage == "encode" and rids:
                for rid in rids:
                    encode[rid] = s.duration_ms
            elif s.stage == "device_execute":
                r = s.replica if s.replica is not None else 0
                dev_spans.setdefault(r, []).append(s)
                for rid in rids or ():
                    device[rid] = s.duration_ms
            elif s.stage == "dispatch":
                r = s.replica if s.replica is not None else 0
                disp_by_replica[r] = disp_by_replica.get(r, 0) + 1
        # percentiles over completed requests only — the same population
        # RunReport.breakdown aggregates
        done = set(complete_t)
        stages = {
            "queue_wait": LatencyStats.of(
                [v for r, v in queue_wait.items() if r in done]),
            "encode": LatencyStats.of(
                [v for r, v in encode.items() if r in done]),
            "device_execute": LatencyStats.of(
                [v for r, v in device.items() if r in done]),
            "total": LatencyStats.of(
                [(complete_t[r] - submit_t[r]) * 1e3
                 for r in done if r in submit_t]),
        }
        all_batch_ms = [s.duration_ms
                        for ss in dev_spans.values() for s in ss]
        fleet_mean = float(np.mean(all_batch_ms)) if all_batch_ms else 0.0
        per_replica: Dict[int, ReplicaTraceStats] = {}
        for r in sorted(set(dev_spans) | set(disp_by_replica)):
            ss = dev_spans.get(r, [])
            ms = [s.duration_ms for s in ss]
            mean = float(np.mean(ms)) if ms else 0.0
            per_replica[r] = ReplicaTraceStats(
                replica=r,
                n_dispatches=disp_by_replica.get(r, 0),
                n_batches=len(ss),
                n_requests=sum(len((s.meta or {}).get("rids") or ())
                               for s in ss),
                busy_s=sum(s.t1 - s.t0 for s in ss),
                mean_batch_ms=mean,
                p95_batch_ms=float(np.percentile(ms, 95)) if ms else 0.0,
                slowdown=mean / fleet_mean if fleet_mean > 0 else 0.0,
            )
        span_s = (max(s.t1 for s in spans) - min(s.t0 for s in spans)) \
            if spans else 0.0
        return cls(stages=stages, counts=counts, per_replica=per_replica,
                   n_spans=len(spans), n_dropped=n_dropped, span_s=span_s)

    def dominant_stage(self) -> Optional[str]:
        """The per-request stage (queue_wait / encode / device_execute)
        with the largest mean — where requests spend their time. None
        when no completed request was traced."""
        cands = [(k, self.stages[k].mean_ms)
                 for k in ("queue_wait", "encode", "device_execute")
                 if self.stages.get(k) is not None and self.stages[k].n]
        if not cands:
            return None
        return max(cands, key=lambda kv: kv[1])[0]

    def as_dict(self) -> Dict[str, object]:
        return {
            "stages": {k: v.as_dict() for k, v in self.stages.items()},
            "counts": dict(self.counts),
            "per_replica": {k: v.as_dict()
                            for k, v in sorted(self.per_replica.items())},
            "dominant_stage": self.dominant_stage(),
            "n_spans": self.n_spans,
            "n_dropped": self.n_dropped,
            "span_s": self.span_s,
        }

    def summary(self) -> str:
        dom = self.dominant_stage()
        parts = [f"{self.n_spans} spans"
                 + (f" ({self.n_dropped} dropped)" if self.n_dropped else "")]
        for k in ("queue_wait", "encode", "device_execute"):
            st = self.stages.get(k)
            if st is not None and st.n:
                parts.append(f"{k} p50/p95 {st.p50_ms:.2f}/{st.p95_ms:.2f} ms"
                             + (" <-- dominant" if k == dom else ""))
        return "; ".join(parts)


# ---------------------------------------------------------------------------
# Rendering + exporters
# ---------------------------------------------------------------------------


def render_timeline(spans: Sequence[Span], rid: int) -> str:
    """One request's lifecycle as a single human-readable line (marks show
    ``stage@t``, spans ``stage[t0..t1]``; times are ms relative to the
    request's first event)."""
    rel = [s for s in spans
           if s.rid == rid or rid in ((s.meta or {}).get("rids") or ())]
    if not rel:
        return f"rid {rid}: (no spans)"
    rel.sort(key=lambda s: (s.t0, s.t1))
    base = rel[0].t0
    parts = []
    for s in rel:
        tag = s.stage
        if s.replica is not None:
            tag += f"(replica={s.replica})"
        if s.meta and "outcome" in s.meta:
            tag += f"[{s.meta['outcome']}]"
        if s.is_mark:
            parts.append(f"{tag}@{(s.t0 - base) * 1e3:.2f}ms")
        else:
            parts.append(f"{tag}[{(s.t0 - base) * 1e3:.2f}"
                         f"..{(s.t1 - base) * 1e3:.2f}ms]")
    return f"rid {rid}: " + " -> ".join(parts)


# Chrome trace lane layout: fixed tids for the shared host-side lanes,
# 10+replica for per-replica device lanes
_TID_ADMISSION = 0
_TID_HOST = 1
_TID_LIFECYCLE = 2
_TID_CONTROLLER = 3
_TID_REPLICA_BASE = 10
_PID = 1


def _lane_of(s: Span) -> tuple:
    if s.stage in ("device_execute", "dispatch"):
        r = s.replica if s.replica is not None else 0
        return _TID_REPLICA_BASE + r, f"replica-{r}"
    if s.stage == "encode":
        return _TID_HOST, "host-encode"
    if s.stage == "controller":
        return _TID_CONTROLLER, "controller"
    if s.stage in ("complete", "drop", "follower_drop"):
        return _TID_LIFECYCLE, "lifecycle"
    return _TID_ADMISSION, "admission"


def chrome_events(spans: Sequence[Span]) -> List[Dict[str, object]]:
    """Spans -> Chrome ``trace_event`` list. Duration spans become ``X``
    events, marks become ``i`` instants, queue waits become async ``b``/
    ``e`` pairs keyed by rid (they overlap arbitrarily, which thread
    lanes cannot render), and ``M`` metadata names the lanes."""
    if not spans:
        return []
    origin = min(s.t0 for s in spans)

    def us(t: float) -> float:
        return (t - origin) * 1e6

    lanes: Dict[int, str] = {}
    evs: List[Dict[str, object]] = []
    for s in spans:
        args: Dict[str, object] = {}
        if s.rid is not None:
            args["rid"] = int(s.rid)
        if s.replica is not None:
            args["replica"] = int(s.replica)
        if s.meta:
            args.update({k: _json_safe(v) for k, v in s.meta.items()})
        if s.stage == "queue_wait":
            common = {"pid": _PID, "cat": "queue_wait",
                      "name": "queue_wait",
                      "id": int(s.rid) if s.rid is not None else 0}
            evs.append({**common, "ph": "b", "ts": us(s.t0), "args": args})
            evs.append({**common, "ph": "e", "ts": us(s.t1)})
            continue
        tid, lane = _lane_of(s)
        lanes.setdefault(tid, lane)
        if s.is_mark:
            evs.append({"pid": _PID, "tid": tid, "ph": "i", "s": "t",
                        "name": s.stage, "ts": us(s.t0), "args": args})
        else:
            evs.append({"pid": _PID, "tid": tid, "ph": "X", "name": s.stage,
                        "ts": us(s.t0),
                        "dur": max(0.0, (s.t1 - s.t0) * 1e6),
                        "args": args})
    meta: List[Dict[str, object]] = [
        {"pid": _PID, "tid": _TID_ADMISSION, "ph": "M",
         "name": "process_name", "args": {"name": "repro.serve"}}]
    for tid, lane in sorted(lanes.items()):
        meta.append({"pid": _PID, "tid": tid, "ph": "M",
                     "name": "thread_name", "args": {"name": lane}})
    return meta + evs

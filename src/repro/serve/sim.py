"""Simulated serving engine for host-device balance studies.

``SimServer`` implements the same prepare/execute protocol as ``LMServer``
but spends *wall-clock sleep* instead of FLOPs: host prepare costs
``host_ms_per_batch + host_ms_per_request * B`` on the calling (dispatcher)
thread, device execute costs ``device_ms_per_batch + device_ms_per_token *
B * max_new`` on the replica worker thread. Sleeps release the GIL, so
replica pipelines genuinely overlap — which is the point: with R replicas
behind one admission path, aggregate throughput scales with R until the
*serial host prepare path* saturates, and the CPU-bound plateau the paper
predicts (§5–6) emerges from real thread timing, not from arithmetic.

Used by ``benchmarks/fig13_endtoend.py --replicas`` (host-device
simulation sweep) and the replica-routing tests, where real accelerators
per replica aren't available in the container.

Outputs are deterministic functions of the request's *content* (prompt
tokens + decode budget — never the rid), so bit-identity checks work
across replica counts and routing policies, and a cached result minted
for one rid is exactly what re-executing a content-equal request under a
different rid would have produced.
"""
from __future__ import annotations

import time
import zlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.serve.engine import Completion, Request


@dataclass
class SimPreparedBatch:
    """Host-side half of a simulated batch (mirrors ``PreparedBatch`` in
    the fields the pipeline layer touches)."""
    requests: List[Request]
    max_new: int


@dataclass(frozen=True)
class SimProfile:
    """A named host/device speed ratio — one of the paper's box shapes.

    The paper's Tables 2–3 argue the FPGA deployment's economics hinge on
    this ratio: an f1.2xlarge-style box (8 vCPUs feeding a big FPGA) is
    host-bound, a c5.12xlarge-style box (48 vCPUs) is balanced. These
    profiles reproduce both regimes (plus the inverse) in ``SimServer``
    milliseconds, so the capacity subsystem can be exercised against each
    without hand-picking sleep times."""
    name: str
    host_ms_per_batch: float
    host_ms_per_request: float
    device_ms_per_batch: float
    device_ms_per_token: float


SIM_PROFILES = {
    # weak 8-vCPU host feeding fast accelerators: the paper's imbalanced
    # cloud box — serial host prepare saturates long before the devices
    "weak_host": SimProfile("weak_host", 4.0, 0.25, 2.0, 0.0),
    # 48-vCPU host, device does real work per batch: neither side idles
    # grossly at moderate load
    "balanced": SimProfile("balanced", 1.0, 0.02, 6.0, 0.0),
    # fast host, slow accelerator: device-bound (more replicas help)
    "weak_device": SimProfile("weak_device", 0.5, 0.0, 12.0, 0.5),
}


@dataclass
class SimServer:
    """LMServer-compatible engine with dialable host/device costs.

    Warm-content model (``warm_factor < 1``): each instance remembers the
    last ``warm_keys`` content keys it executed; re-executing one of them
    costs ``warm_factor`` of the cold per-request device cost — the
    accelerator-local warm state (resident rule tables, primed buffers)
    that makes recomputing expired content cheaper *on the replica that
    produced it*. With per-replica SimServer instances this is exactly the
    placement signal hit-aware routing exploits; ``warm_factor=1.0``
    (default) disables the model and keeps costs purely size-driven.
    Tokens remain content-pure either way — warmth changes *time*, never
    *bits*."""
    vocab: int = 256
    host_ms_per_batch: float = 1.0
    host_ms_per_request: float = 0.0
    device_ms_per_batch: float = 4.0
    device_ms_per_token: float = 0.0
    warm_factor: float = 1.0
    warm_keys: int = 512
    sleep: object = field(default=time.sleep, repr=False)
    _warm: "OrderedDict" = field(default_factory=OrderedDict, init=False,
                                 repr=False)

    @classmethod
    def from_profile(cls, profile, **overrides) -> "SimServer":
        """Build from a :class:`SimProfile` or a ``SIM_PROFILES`` name."""
        if isinstance(profile, str):
            profile = SIM_PROFILES[profile]
        kw = dict(host_ms_per_batch=profile.host_ms_per_batch,
                  host_ms_per_request=profile.host_ms_per_request,
                  device_ms_per_batch=profile.device_ms_per_batch,
                  device_ms_per_token=profile.device_ms_per_token)
        kw.update(overrides)
        return cls(**kw)

    # -- host-side prepare stage --------------------------------------------
    def prepare_batch(self, requests: Sequence[Request]) -> SimPreparedBatch:
        rs = list(requests)
        cost = (self.host_ms_per_batch
                + self.host_ms_per_request * len(rs)) * 1e-3
        if cost > 0:
            self.sleep(cost)
        return SimPreparedBatch(
            requests=rs,
            max_new=max((r.max_new_tokens for r in rs), default=0))

    # -- device-side execute stage ------------------------------------------
    def execute_prepared(self, pb: SimPreparedBatch, *,
                         device=None) -> List[Completion]:
        rs = pb.requests
        if not rs:
            return []
        per_req = self.device_ms_per_token * pb.max_new
        if self.warm_factor < 1.0:
            # warm rows run at a discount; every executed row (re)warms
            # its content key. _warm is touched only from this instance's
            # replica worker thread, so no lock is needed
            keys = [self._content_key(r) for r in rs]
            n_warm = sum(1 for k in keys if k in self._warm)
            row_cost = per_req * (len(rs) - n_warm
                                  + n_warm * self.warm_factor)
            for k in keys:
                self._warm.pop(k, None)
                self._warm[k] = True
            while len(self._warm) > max(1, self.warm_keys):
                self._warm.popitem(last=False)
        else:
            row_cost = per_req * len(rs)
        cost = (self.device_ms_per_batch + row_cost) * 1e-3
        if cost > 0:
            self.sleep(cost)
        return [Completion(rid=r.rid,
                           tokens=self._tokens(r),
                           prefill_ms=0.0,
                           decode_ms=cost * 1e3,
                           batch_size=len(rs))
                for r in rs]

    def generate_batch(self, requests: Sequence[Request]) -> List[Completion]:
        if not requests:
            return []
        return self.execute_prepared(self.prepare_batch(requests))

    def _content_key(self, r: Request) -> tuple:
        # same content notion as cache.request_key (tokens + decode
        # budget), cheap enough to compute per executed row
        return (zlib.crc32(np.ascontiguousarray(
            np.asarray(r.tokens, np.int64)).tobytes()),
            int(r.max_new_tokens))

    def _tokens(self, r: Request) -> np.ndarray:
        # deterministic in the request's CONTENT alone (never the rid):
        # identical across replicas, routing policies, batch compositions,
        # and rid renumbering — the bit-identity anchor that also makes
        # result-cache substitution exact for content-equal requests
        n = r.max_new_tokens
        base = zlib.crc32(
            np.ascontiguousarray(np.asarray(r.tokens, np.int64)).tobytes())
        return ((int(base) * 1009 + n * 131
                 + np.arange(n, dtype=np.int64) * 31 + 7)
                % self.vocab).astype(np.int32)


def sim_requests(n: int, *, max_new_tokens: int = 4, prompt_len: int = 8,
                 arrivals: Optional[np.ndarray] = None,
                 rid_base: int = 0, vocab: int = 256,
                 skew: Optional[Sequence[int]] = None,
                 unique_keys: int = 0, repeat_alpha: float = 0.0,
                 content_seed: Optional[int] = None) -> List[Request]:
    """Deterministic request stream for simulation runs.

    ``skew`` cycles per-request decode lengths (e.g. ``(16, 1)`` alternates
    heavy/light). ``unique_keys``/``repeat_alpha`` switch to repeat-heavy
    traffic: contents drawn from ``unique_keys`` prototypes under
    Zipf(``repeat_alpha``) popularity (cache studies). ``content_seed``
    pins the content RNG independently of ``rid_base`` so a second wave of
    fresh rids can replay the *same* key population (defaults to
    ``rid_base + 7``, the original behavior).
    """
    rng = np.random.default_rng(content_seed if content_seed is not None
                                else rid_base + 7)
    protos: Optional[List[np.ndarray]] = None
    choice: Optional[np.ndarray] = None
    if unique_keys > 0:
        from repro.serve.loadgen import zipf_probs
        protos = [rng.integers(1, vocab, prompt_len).astype(np.int32)
                  for _ in range(unique_keys)]
        choice = rng.choice(unique_keys, size=n,
                            p=zipf_probs(unique_keys, repeat_alpha))
    out = []
    for i in range(n):
        # in prototype mode, decode length follows the prototype (not the
        # stream position) so content-equal requests stay cache-equal
        j = int(choice[i]) if choice is not None else i
        mn = skew[j % len(skew)] if skew else max_new_tokens
        toks = protos[j].copy() if protos is not None \
            else rng.integers(1, vocab, prompt_len).astype(np.int32)
        out.append(Request(
            rid=rid_base + i,
            tokens=toks,
            max_new_tokens=int(mn),
            arrival=float(arrivals[i]) if arrivals is not None else 0.0))
    return out

"""Simulated serving engine for host-device balance studies.

``SimServer`` implements the same prepare/execute protocol as ``LMServer``
but spends *wall-clock sleep* instead of FLOPs: host prepare costs
``host_ms_per_batch + host_ms_per_request * B`` on the calling (dispatcher)
thread, device execute costs ``device_ms_per_batch + device_ms_per_token *
B * max_new`` on the replica worker thread. Sleeps release the GIL, so
replica pipelines genuinely overlap — which is the point: with R replicas
behind one admission path, aggregate throughput scales with R until the
*serial host prepare path* saturates, and the CPU-bound plateau the paper
predicts (§5–6) emerges from real thread timing, not from arithmetic.

Used by ``benchmarks/fig13_endtoend.py --replicas`` (host-device
simulation sweep) and the replica-routing tests, where real accelerators
per replica aren't available in the container.

Outputs are deterministic functions of the request (rid + position), so
bit-identity checks work across replica counts and routing policies.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.serve.engine import Completion, Request


@dataclass
class SimPreparedBatch:
    """Host-side half of a simulated batch (mirrors ``PreparedBatch`` in
    the fields the pipeline layer touches)."""
    requests: List[Request]
    max_new: int


@dataclass
class SimServer:
    """LMServer-compatible engine with dialable host/device costs."""
    vocab: int = 256
    host_ms_per_batch: float = 1.0
    host_ms_per_request: float = 0.0
    device_ms_per_batch: float = 4.0
    device_ms_per_token: float = 0.0
    sleep: object = field(default=time.sleep, repr=False)

    # -- host-side prepare stage --------------------------------------------
    def prepare_batch(self, requests: Sequence[Request]) -> SimPreparedBatch:
        rs = list(requests)
        cost = (self.host_ms_per_batch
                + self.host_ms_per_request * len(rs)) * 1e-3
        if cost > 0:
            self.sleep(cost)
        return SimPreparedBatch(
            requests=rs,
            max_new=max((r.max_new_tokens for r in rs), default=0))

    # -- device-side execute stage ------------------------------------------
    def execute_prepared(self, pb: SimPreparedBatch, *,
                         device=None) -> List[Completion]:
        rs = pb.requests
        if not rs:
            return []
        cost = (self.device_ms_per_batch
                + self.device_ms_per_token * len(rs) * pb.max_new) * 1e-3
        if cost > 0:
            self.sleep(cost)
        return [Completion(rid=r.rid,
                           tokens=self._tokens(r),
                           prefill_ms=0.0,
                           decode_ms=cost * 1e3,
                           batch_size=len(rs))
                for r in rs]

    def generate_batch(self, requests: Sequence[Request]) -> List[Completion]:
        if not requests:
            return []
        return self.execute_prepared(self.prepare_batch(requests))

    def _tokens(self, r: Request) -> np.ndarray:
        # deterministic in the request alone: identical across replicas,
        # routing policies, and batch compositions (bit-identity anchor)
        n = r.max_new_tokens
        return ((int(r.rid) * 1009 + np.arange(n, dtype=np.int64) * 31 + 7)
                % self.vocab).astype(np.int32)


def sim_requests(n: int, *, max_new_tokens: int = 4, prompt_len: int = 8,
                 arrivals: Optional[np.ndarray] = None,
                 rid_base: int = 0, vocab: int = 256,
                 skew: Optional[Sequence[int]] = None) -> List[Request]:
    """Deterministic request stream for simulation runs; ``skew`` cycles
    per-request decode lengths (e.g. ``(16, 1)`` alternates heavy/light)."""
    rng = np.random.default_rng(rid_base + 7)
    out = []
    for i in range(n):
        mn = skew[i % len(skew)] if skew else max_new_tokens
        out.append(Request(
            rid=rid_base + i,
            tokens=rng.integers(1, vocab, prompt_len).astype(np.int32),
            max_new_tokens=int(mn),
            arrival=float(arrivals[i]) if arrivals is not None else 0.0))
    return out

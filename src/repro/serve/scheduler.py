"""Asynchronous submission pipeline with bounded admission + backpressure.

The paper's deployment lesson (§5–6): the accelerator's headline gains
evaporate when the host submission path can't keep it fed — batches form
too slowly, the CPU saturates first, and end-to-end the system gets slower
*and* more expensive. This module makes that regime reproducible:

    submit() --bounded queue / backpressure--> [batcher thread]
        host prepare (token matrix + MCT encode, numpy)
              --replica routing--> [per-replica device threads]
        rule match + decode loop on the accelerator(s)

The batcher routes each prepared batch to one replica of an
:class:`~repro.serve.group.EngineGroup` (least-outstanding-work by default,
``sticky`` for deterministic replay, ``hit_aware`` for cache-ownership
affinity with a straggler-guarded spill — see
:class:`~repro.serve.group.RoutingPolicy`). Every replica keeps its own
depth-``pipeline_depth`` handoff queue (2 = classic double buffering), so
host-side encode of batch N+1 overlaps device execution of batch N — and
with several replicas, host work for one replica overlaps device work on
the others. ``jax.block_until_ready`` inside the device stage marks the
true device-busy interval for the per-replica idle-fraction metric.

Backpressure policies (:class:`BackpressurePolicy`) when the admission
queue (pending + aggregator buffer) is at ``max_queue``:

- ``REJECT``      — refuse the new request (submit returns False)
- ``SHED_OLDEST`` — evict the oldest queued request, admit the new one
- ``BLOCK``       — make the submitter wait (closed-loop behaviour)

With a :class:`~repro.serve.cache.CacheConfig` on the config, ``submit``
checks the content-addressed :class:`~repro.serve.cache.ResultCache`
first (a hit completes immediately — zero host encode, zero device time)
and then the single-flight :class:`~repro.serve.cache.Coalescer` (an
identical in-flight request adopts the new one as a follower). Hits and
followers never occupy admission-queue space, so they are exempt from all
three backpressure policies; a shed leader drops its followers with it.

With a :class:`~repro.serve.trace.TraceConfig` on the config (or a shared
:class:`~repro.serve.trace.Tracer` passed in), every lifecycle step —
submit, cache lookup, admission, queue wait, encode, dispatch, device
execute, completion/shed/drop — lands as a span on one timeline, using
the same timestamps the metrics layer records. ``trace=None`` (default)
emits nothing and keeps the stack bit-identical to its untraced behavior.
"""
from __future__ import annotations

import enum
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Union

from repro.core.aggregator import DeadlineAggregator
from repro.serve.cache import (CacheConfig, CachedResult, Coalescer,
                               NegativeResult, ResultCache, request_key)
from repro.serve.capacity import CapacityConfig, CapacityController
from repro.serve.config import coerce_enum
from repro.serve.engine import Completion, LMServer, Request
from repro.serve.group import EngineGroup, RoutingPolicy
from repro.serve.metrics import MetricsCollector
from repro.serve.trace import TraceConfig, Tracer, TraceReport


class BackpressurePolicy(str, enum.Enum):
    """What happens to a new request when the admission queue is full."""
    REJECT = "reject"
    SHED_OLDEST = "shed_oldest"
    BLOCK = "block"

    def __str__(self) -> str:            # StrEnum parity on py3.10
        return self.value


# legacy tuple kept for callers that introspected the valid policy strings
POLICIES = tuple(p.value for p in BackpressurePolicy)


@dataclass
class SchedulerConfig:
    target_batch: int = 8
    deadline: float = 0.05          # seconds a request may wait for peers
    max_queue: int = 64             # bounded admission depth (requests)
    policy: Union[str, BackpressurePolicy] = BackpressurePolicy.REJECT
    pipeline_depth: int = 2         # prepared batches in flight per replica
                                    # (2 = double buffering)
    devices: Optional[Sequence] = None  # one replica per device
    replicas: Optional[int] = None      # colocated replicas (simulation)
    routing: Union[str, RoutingPolicy] = RoutingPolicy.LEAST_LOADED
    # hit_aware guard knobs (inert under other routing policies):
    # outstanding-work gap over the least-loaded candidate beyond which
    # the affinity preference spills; latency-EWMA multiple of the other
    # replicas' mean that marks the owner a straggler; EWMA smoothing
    spill_threshold: int = 96
    straggler_factor: float = 2.0
    ewma_alpha: float = 0.25
    # result cache + coalescing (None/False = off, True = defaults,
    # dict/CacheConfig = explicit knobs)
    cache: Union[None, bool, dict, CacheConfig] = None
    # capacity control loop (None/False = off — bit-identical to the
    # uncontrolled stack, True = defaults, dict/CapacityConfig = knobs)
    capacity: Union[None, bool, dict, CapacityConfig] = None
    # per-request tracing (None/False = off — zero emission, bit-identical
    # stack; True = defaults, dict/TraceConfig = knobs)
    trace: Union[None, bool, dict, TraceConfig] = None

    def __post_init__(self):
        # every optional subsystem uses the one shared coercion rule
        # (repro.serve.config.coerce): None/False off, True defaults,
        # dict kwargs, instance as-is
        self.cache = CacheConfig.coerce(self.cache)
        self.capacity = CapacityConfig.coerce(self.capacity)
        self.trace = TraceConfig.coerce(self.trace)
        self.policy = coerce_enum(BackpressurePolicy, self.policy,
                                  field="policy")
        self.routing = coerce_enum(RoutingPolicy, self.routing,
                                   field="routing")
        if self.spill_threshold < 0:
            raise ValueError(f"spill_threshold must be >= 0, "
                             f"got {self.spill_threshold}")
        if self.straggler_factor < 1.0:
            raise ValueError(f"straggler_factor must be >= 1.0, "
                             f"got {self.straggler_factor}")
        if not (0.0 < self.ewma_alpha <= 1.0):
            raise ValueError(f"ewma_alpha must be in (0, 1], "
                             f"got {self.ewma_alpha}")


class AsyncScheduler:
    """Live continuous-batching front end with bounded admission.

    Accepts a single ``LMServer`` (wrapped into a one-replica
    :class:`EngineGroup`; ``devices``/``replicas`` in the config expand it)
    or an ``EngineGroup`` built explicitly. Thread layout: submitters call
    :meth:`submit`; a batcher thread drains the admission queue through a
    :class:`DeadlineAggregator` (wall-clock deadline), host-prepares one
    batch at a time, and routes it to a replica pipeline. Draining one
    batch per poll is what makes backpressure real — overload accumulates
    in the *bounded* admission queue instead of an unbounded internal
    buffer.
    """

    def __init__(self, server: Union[LMServer, EngineGroup],
                 config: Optional[SchedulerConfig] = None, *,
                 metrics: Optional[MetricsCollector] = None,
                 on_complete: Optional[Callable[[Completion], None]] = None,
                 cache: Optional[ResultCache] = None,
                 tracer: Optional[Tracer] = None,
                 **overrides):
        if config is None:
            config = SchedulerConfig(**overrides)
        elif overrides:
            raise ValueError("pass either config or keyword overrides")
        self.cfg = config
        if isinstance(server, EngineGroup):
            self.group = server         # config.routing/devices ignored:
                                        # the group already encodes them
        else:
            self.group = EngineGroup.from_server(
                server, devices=config.devices, replicas=config.replicas,
                routing=config.routing,
                spill_threshold=config.spill_threshold,
                straggler_factor=config.straggler_factor,
                ewma_alpha=config.ewma_alpha)
        self.server = self.group.replicas[0].server
        self.metrics = metrics if metrics is not None else MetricsCollector()
        # result cache: an explicit instance (Server shares one across
        # sessions and replicas) wins over the config's CacheConfig
        if cache is not None:
            self.cache = cache
        elif config.cache is not None:
            self.cache = ResultCache(config.cache)
        else:
            self.cache = None
        self._coalescer = Coalescer(enabled=self.cache.cfg.coalesce) \
            if self.cache is not None else None
        # tracer: an explicit instance (Server shares one across sessions)
        # wins over the config's TraceConfig; None = zero emission
        if tracer is not None:
            self.tracer = tracer
        elif config.trace is not None:
            self.tracer = Tracer(config.trace)
        else:
            self.tracer = None
        # queue-wait start per admitted rid (the same arrival value handed
        # to metrics.on_arrival) — maintained only when tracing is on
        self._admit_t: Dict[int, float] = {}
        self._epoch = time.perf_counter()
        self._lock = threading.Lock()
        self._have_work = threading.Condition(self._lock)
        self._space = threading.Condition(self._lock)
        self._pending: deque = deque()
        self._agg = DeadlineAggregator(target_batch=config.target_batch,
                                       deadline=config.deadline)
        # live admission limit — the capacity controller's AIMD knob;
        # starts at (and without a controller stays at) config.max_queue
        self._max_queue = config.max_queue
        self._closed = False
        self.n_submitted = 0
        self.n_rejected = 0
        self.n_shed = 0
        self.n_cache_hits = 0
        self.n_coalesced = 0
        self.n_negative_hits = 0
        # completions minted off the pipeline (cache hits + resolved
        # followers), merged into result()
        self._extra: List[Completion] = []
        # the run always gets the scheduler's own hooks; user callbacks
        # (closed-loop generators chain onto the properties below) live in
        # these slots so cache/coalescer bookkeeping can't be displaced
        self._user_on_complete = on_complete
        self._user_on_drop: Optional[Callable[[int], None]] = None
        self._run = self.group.open(pipeline_depth=config.pipeline_depth,
                                    metrics=self.metrics,
                                    clock=self._now,
                                    on_complete=self._complete_hook,
                                    on_drop=self._drop_hook,
                                    tracer=self.tracer,
                                    cache=self.cache)
        self._batcher = threading.Thread(target=self._batch_loop, daemon=True)
        self._batcher_error: Optional[BaseException] = None
        self._started = False
        self._results: Optional[List[Completion]] = None
        # capacity control loop (None = fully unwired: every knob keeps
        # its configured value and the stack is bit-identical)
        self._controller: Optional[CapacityController] = None
        if config.capacity is not None:
            self._controller = CapacityController(
                self, config.capacity, metrics=self.metrics,
                clock=self._now, tracer=self.tracer)

    # -- time ----------------------------------------------------------------
    def _now(self) -> float:
        return time.perf_counter() - self._epoch

    # completion/drop hooks (closed-loop generators chain onto these).
    # The GroupRun always calls the scheduler's internal hooks, which do
    # cache fill + follower resolution and then forward to these user
    # slots — so chaining can never displace the cache bookkeeping.
    @property
    def on_complete(self):
        return self._user_on_complete

    @on_complete.setter
    def on_complete(self, cb):
        self._user_on_complete = cb

    @property
    def on_drop(self):
        return self._user_on_drop

    @on_drop.setter
    def on_drop(self, cb):
        self._user_on_drop = cb

    # -- cache/coalescer plumbing (run on the replica worker threads) --------
    def _complete_hook(self, comp: Completion):
        """Leader completed: fill the cache, mint follower completions,
        then forward everything to the user callback."""
        minted: List[Completion] = []
        if self.cache is not None:
            now = self._now()
            key, followers = self._coalescer.resolve(comp.rid)
            if key is not None:
                entry = CachedResult.of(
                    comp, replica=self.metrics.replica_of(comp.rid), now=now)
                self.cache.put(key, entry, metrics=self.metrics,
                               tracer=self.tracer, rid=comp.rid)
                for freq in followers:
                    minted.append(entry.mint(freq.rid))
                    self.metrics.on_complete([freq.rid], now)
                    if self.tracer is not None:
                        self.tracer.mark("complete", now, rid=freq.rid,
                                         source="coalesce")
            if minted:
                with self._lock:
                    self._extra.extend(minted)
        cb = self._user_on_complete
        if cb is not None:
            cb(comp)
            for fc in minted:
                cb(fc)

    def _drop_hook(self, rid: int, *, filtered: bool = True):
        """Leader shed or dropped: its followers are dropped with it —
        never independently — and the key is released so the next
        identical request becomes a fresh leader. ``filtered`` is True on
        the engine-drop path (the GroupRun calls this positionally for
        rids the MCT feasibility check removed), where the verdict is a
        property of the *content* and worth negative-caching; a shed is
        a property of the *moment* and is not."""
        followers: List[Request] = []
        if self._coalescer is not None:
            key, followers = self._coalescer.fail(rid)
            if followers:
                self.metrics.on_cache("follower_drops", len(followers))
                if self.tracer is not None:
                    now = self._now()
                    for freq in followers:
                        self.tracer.mark("follower_drop", now,
                                         rid=freq.rid, leader=rid)
            if filtered and key is not None and self.cache is not None:
                self.cache.put_negative(key, self._now(),
                                        metrics=self.metrics,
                                        tracer=self.tracer, rid=rid)
        cb = self._user_on_drop
        if cb is not None:
            cb(rid)
            for freq in followers:
                cb(freq.rid)

    # -- public API ------------------------------------------------------------
    def start(self) -> "AsyncScheduler":
        with self._lock:
            if self._started:
                return self
            self._started = True
        self._run.start()
        self._batcher.start()
        if self._controller is not None:
            self._controller.start()
        return self

    # -- capacity actuator protocol (driven by CapacityController) -----------
    def capacity_state(self) -> dict:
        """Live knob values + load state for the capacity controller."""
        with self._lock:
            depth = self._depth_locked()
            tb = self._agg.target_batch
            limit = self._max_queue
        return {"queue_depth": depth, "target_batch": tb,
                "admission_limit": limit,
                "n_active": self._run.n_active,
                "n_replicas": len(self.group.replicas),
                "replica_depths": tuple(self._run.outstanding())}

    def set_target_batch(self, n: int) -> None:
        """Retarget batch formation live (next poll sees it)."""
        with self._lock:
            self._agg.target_batch = max(1, int(n))
            self._have_work.notify()    # a smaller target may make a
                                        # buffered batch ready now

    def set_admission_limit(self, n: int) -> None:
        """Rescale the bounded admission depth live (AIMD knob)."""
        with self._lock:
            self._max_queue = max(1, int(n))
            self._space.notify_all()    # a raised limit unblocks waiters

    def set_active_replicas(self, n: int) -> int:
        """Activate/park replicas (parked ones drain, attract no new
        dispatches)."""
        return self._run.set_active(n)

    def _depth_locked(self) -> int:
        return len(self._pending) + self._agg.pending()

    def _pipeline_dead(self) -> bool:
        return self._batcher_error is not None \
            or self._run.error is not None

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return self._depth_locked()

    def submit(self, req: Request, *, arrival: Optional[float] = None) -> bool:
        """Offer a request; returns False when rejected by backpressure.

        With a result cache configured, the content-addressed fast paths
        run first, ahead of admission: a cache hit completes immediately
        and an identical in-flight request adopts this one as a follower.
        Neither consumes queue space, so neither can be rejected, shed, or
        blocked — backpressure only ever acts on leaders."""
        self.start()                 # idempotent, lock-guarded
        now = self._now()
        tr = self.tracer
        arr = arrival if arrival is not None else now
        shed_rid: Optional[int] = None
        promoted_drops: List[int] = []
        hit: Optional[Completion] = None
        negative = False
        key: Optional[str] = None
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            if self.cache is not None:
                key = request_key(req)
                entry = self.cache.get(key, now, metrics=self.metrics,
                                       tracer=tr, rid=req.rid)
                if isinstance(entry, NegativeResult):
                    # known-filtered content: drop at submit time, zero
                    # queue space / host encode / device time
                    negative = True
                    self.n_submitted += 1
                    self.n_negative_hits += 1
                    self.metrics.on_arrival(req.rid, arrival
                                            if arrival is not None else now)
                    self.metrics.on_cache("negative_hits")
                    if tr is not None:
                        tr.mark("submit", arr, rid=req.rid)
                        tr.mark("negative_drop", now, rid=req.rid)
                elif entry is not None:
                    hit = entry.mint(req.rid)
                    self.n_submitted += 1
                    self.n_cache_hits += 1
                    self._extra.append(hit)
                    self.metrics.on_arrival(req.rid, arrival
                                            if arrival is not None else now)
                    self.metrics.on_cache_hit(req.rid, now,
                                              replica=entry.replica)
                    self.metrics.on_complete([req.rid], now)
                    if tr is not None:
                        tr.mark("submit", arr, rid=req.rid)
                        tr.mark("complete", now, rid=req.rid,
                                source="cache")
                else:
                    leader = self._coalescer.attach(key, req)
                    if leader is not None:
                        self.n_submitted += 1
                        self.n_coalesced += 1
                        self.metrics.on_arrival(
                            req.rid, arrival if arrival is not None else now)
                        self.metrics.on_coalesce(req.rid, leader, now)
                        if tr is not None:
                            tr.mark("submit", arr, rid=req.rid)
                            tr.mark("coalesce", now, rid=req.rid,
                                    leader=leader)
                        return True
            if hit is None and not negative:
                if self.cfg.policy == BackpressurePolicy.BLOCK:
                    while self._depth_locked() >= self._max_queue \
                            and not self._closed \
                            and not self._pipeline_dead():
                        self._space.wait(timeout=0.1)
                    if self._closed:
                        # close() raced our wait; the batcher may already
                        # have flushed and exited — appending now would
                        # lose the request silently
                        raise RuntimeError("scheduler is closed")
                    if self._pipeline_dead():
                        # the batcher/device thread died, so queue space
                        # will never free up — fail fast instead of
                        # wedging the submitter (result() carries the
                        # root cause)
                        raise RuntimeError("scheduler pipeline failed; "
                                           "call result() for the cause")
                elif self._depth_locked() >= self._max_queue:
                    if self.cfg.policy == BackpressurePolicy.REJECT:
                        self.n_rejected += 1
                        self.metrics.on_reject(req.rid, now)
                        if tr is not None:
                            tr.mark("submit", arr, rid=req.rid)
                            tr.mark("reject", now, rid=req.rid)
                        return False
                    # shed_oldest: evict from the aggregator buffer first
                    # (the overall oldest), then from the pending deque.
                    # A shed coalescing leader with followers promotes its
                    # first follower instead of killing the whole flight
                    # (promote_on_shed): the promoted follower takes a
                    # queue slot as the new leader, so eviction continues
                    # until a slot genuinely frees up — each promotion
                    # consumes one follower, so this terminates
                    while self._depth_locked() >= self._max_queue:
                        victim = self._agg.evict_oldest(now)
                        if victim is None and self._pending:
                            victim = self._pending.popleft()
                        if victim is None:
                            break
                        vrid = victim[1].rid
                        self.n_shed += 1
                        self.metrics.on_shed(vrid, now)
                        if tr is not None:
                            tr.mark("shed", now, rid=vrid)
                            self._admit_t.pop(vrid, None)
                        promoted = None
                        if self._coalescer is not None \
                                and self.cache.cfg.promote_on_shed:
                            promoted = self._coalescer.promote(vrid)
                        if promoted is None:
                            shed_rid = vrid
                            break
                        self.metrics.on_cache("leader_promotions")
                        self.metrics.on_admit(promoted.rid, now)
                        if tr is not None:
                            tr.mark("admit", now, rid=promoted.rid,
                                    promoted_from=vrid)
                            # queue wait starts where the breakdown's
                            # does: the follower's recorded arrival
                            pa = self.metrics.arrival_of(promoted.rid)
                            self._admit_t[promoted.rid] = \
                                pa if pa is not None else now
                        # re-admit at the tail of pending (not the
                        # aggregator): evict_oldest drains the aggregator
                        # first, so the promoted leader must not land
                        # there or this same pass would evict it next and
                        # kill the flight it just saved
                        self._pending.append((promoted.rid, promoted))
                        promoted_drops.append(vrid)
                self._pending.append((req.rid, req))
                self.n_submitted += 1
                # arrival/admit recorded only once the request's fate is
                # decided — a submit that raised on a close() race must
                # not leave a phantom trace inflating the report
                self.metrics.on_arrival(req.rid, arrival
                                        if arrival is not None else now)
                self.metrics.on_admit(req.rid, now)
                if tr is not None:
                    tr.mark("submit", arr, rid=req.rid)
                    tr.mark("admit", now, rid=req.rid)
                    self._admit_t[req.rid] = arr
                self.metrics.note_queue_depth(self._depth_locked())
                if key is not None:
                    # admitted leader: claim the key so identical requests
                    # coalesce onto it until it completes or is shed
                    self._coalescer.claim(key, req.rid)
                    self.metrics.on_cache_miss(req.rid)
                self._have_work.notify()
        # user callbacks outside the non-reentrant lock: an on_complete/
        # on_drop that reads queue_depth or re-submits must not deadlock
        # (the device thread already calls them unlocked — same contract)
        if negative:
            cb = self._user_on_drop
            if cb is not None:
                cb(req.rid)
            return True
        if hit is not None:
            cb = self._user_on_complete
            if cb is not None:
                cb(hit)
            return True
        for vrid in promoted_drops:
            # promoted-away leaders: the flight survives under the new
            # leader, so only the user drop callback fires — no coalescer
            # fail, no follower drops, no negative store
            cb = self._user_on_drop
            if cb is not None:
                cb(vrid)
        if shed_rid is not None:
            self._drop_hook(shed_rid, filtered=False)
        return True

    def close(self):
        """Stop accepting requests and flush everything still queued."""
        # stop the control loop before taking the lock (its tick reads
        # capacity_state under the same lock); knobs freeze at their
        # final values for the drain
        if self._controller is not None:
            self._controller.stop()
        with self._lock:
            self._closed = True
            self._have_work.notify_all()
            self._space.notify_all()

    def result(self) -> List[Completion]:
        """close() if needed, wait for the pipeline to drain, and return
        all completions (matched by rid; cross-replica order is not
        meaningful)."""
        if self._results is not None:
            return self._results
        if not self._started:
            self.start()       # zero submissions: drain cleanly to []
        self.close()
        self._batcher.join()
        completions = self._run.finish()        # raises on replica error
        if self._batcher_error is not None:
            raise RuntimeError("batcher thread failed") \
                from self._batcher_error
        with self._lock:
            # cache hits + resolved followers never ran on a replica;
            # merge them in (callers match by rid)
            completions = completions + self._extra
        self._results = completions
        return self._results

    def shutdown(self) -> None:
        """close() + reap the batcher and every replica worker thread,
        swallowing pipeline errors — the exception-path cleanup used by
        the context manager, so a failed run never leaks the pipeline
        threads. Use :meth:`result` when you want errors raised."""
        try:
            self.result()
        except Exception:
            pass

    def __enter__(self) -> "AsyncScheduler":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is None:
            self.result()
        else:
            # body raised: reap threads without masking the user's error
            self.shutdown()
        return False

    def report(self, *, offered_qps: Optional[float] = None):
        if self.cache is not None:
            self.metrics.note_cache_bytes(self.cache.bytes_resident,
                                          len(self.cache))
        rep = self.metrics.report(offered_qps=offered_qps)
        rep.n_rejected = max(rep.n_rejected, self.n_rejected)
        rep.n_shed = max(rep.n_shed, self.n_shed)
        if self._controller is not None:
            rep.capacity = {**rep.capacity, **self._controller.summary()}
        return rep

    def trace_report(self) -> Optional[TraceReport]:
        """Per-stage percentiles + straggler attribution derived from this
        session's spans (None when tracing is off)."""
        return self.tracer.report() if self.tracer is not None else None

    # -- batcher thread --------------------------------------------------------
    def _take_batch(self) -> Optional[List[Request]]:
        """Block until one batch is ready (target size or deadline) or the
        scheduler is closed and drained. Returns None to stop."""
        with self._lock:
            while True:
                # move newly-submitted requests into the aggregator, then
                # drain at most ONE batch — overload stays in the bounded
                # admission state where backpressure can see it
                now = self._now()
                while self._pending:
                    rid, req = self._pending.popleft()
                    self._agg.add(rid, [req], now=now)
                batches = self._agg.poll(now, limit=1)
                if batches:
                    self._space.notify_all()
                    return [q for q in batches[0].queries]
                if self._closed:
                    batches = self._agg.flush()
                    if batches:
                        self._space.notify_all()
                        return [q for q in batches[0].queries]
                    return None
                # idle: sleep until a submit/close notification; partial
                # batch buffered: sleep just long enough to fire its
                # deadline flush (no busy-ticking in either case)
                nd = self._agg.next_deadline()
                timeout = None if nd is None \
                    else max(nd - self._now(), 1e-4)
                self._have_work.wait(timeout=timeout)

    def _batch_loop(self):
        try:
            while True:
                rs = self._take_batch()
                if rs is None:
                    return
                t0 = self._now()
                pb = self.group.prepare_batch(rs)
                t1 = self._now()
                self.metrics.on_encode([r.rid for r in rs], t0, t1)
                if self.tracer is not None:
                    rids = [r.rid for r in rs]
                    # queue wait ends where encode begins — the same t0
                    # the breakdown uses as encode_start
                    for rid in rids:
                        a = self._admit_t.pop(rid, None)
                        if a is not None:
                            self.tracer.span("queue_wait", a, t0, rid=rid)
                    self.tracer.span("encode", t0, t1, rids=rids)
                # blocks while the routed replica already has
                # `pipeline_depth` batches in flight — that stall is what
                # pushes overload back onto the bounded admission queue
                self._run.dispatch(pb)
        except BaseException as e:          # surfaced by result()
            self._batcher_error = e
            with self._lock:
                # blocked submitters must not wait for space that will
                # never free up
                self._space.notify_all()
                self._have_work.notify_all()

"""Asynchronous submission pipeline with bounded admission + backpressure.

The paper's deployment lesson (§5–6): the accelerator's headline gains
evaporate when the host submission path can't keep it fed — batches form
too slowly, the CPU saturates first, and end-to-end the system gets slower
*and* more expensive. This module makes that regime reproducible:

    submit() --bounded queue / backpressure--> [batcher thread]
        host prepare (token matrix + MCT encode, numpy)
              --depth-k handoff--> [device thread]
        rule match + decode loop on the accelerator

The handoff queue holds ``pipeline_depth`` prepared batches (2 = classic
double buffering): host-side encode of batch N+1 overlaps device execution
of batch N; ``jax.block_until_ready`` inside the device stage marks the
true device-busy interval for the idle-fraction metric.

Backpressure policies when the admission queue (pending + aggregator
buffer) is at ``max_queue``:

- ``reject``      — refuse the new request (submit returns False)
- ``shed_oldest`` — evict the oldest queued request, admit the new one
- ``block``       — make the submitter wait (closed-loop behaviour)

``run_pipelined`` is the deterministic sibling: it takes pre-formed batch
groups (logical-time aggregation, see ``LMServer.form_batches``) and pushes
them through the same two-stage pipeline — bit-identical completions to the
synchronous baseline, overlapped host/device work.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

from repro.core.aggregator import DeadlineAggregator
from repro.serve.engine import Completion, LMServer, Request
from repro.serve.metrics import MetricsCollector

POLICIES = ("reject", "shed_oldest", "block")


@dataclass
class SchedulerConfig:
    target_batch: int = 8
    deadline: float = 0.05          # seconds a request may wait for peers
    max_queue: int = 64             # bounded admission depth (requests)
    policy: str = "reject"
    pipeline_depth: int = 2         # prepared batches in flight (2 = double
                                    # buffering)
    devices: Optional[Sequence] = None  # round-robin device placement

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}")


class _DeviceWorker:
    """Consumes prepared batches from the handoff queue, executes them on
    the device (round-robin when several), records busy intervals."""

    def __init__(self, server: LMServer, depth: int, metrics,
                 on_complete: Optional[Callable[[Completion], None]] = None,
                 on_drop: Optional[Callable[[int], None]] = None,
                 devices=None, clock=time.perf_counter):
        self.server = server
        self.handoff: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
        self.metrics = metrics
        self.on_complete = on_complete
        self.on_drop = on_drop          # rid sinks without a Completion
        self.devices = list(devices) if devices else [None]
        self.clock = clock
        self.completions: List[Completion] = []
        self.error: Optional[BaseException] = None
        self._n = 0
        self._thread = threading.Thread(target=self._loop, daemon=True)

    def start(self):
        self._thread.start()

    def put(self, pb):
        # bounded put that stays responsive to worker death: if the device
        # thread died with the queue full, a plain put() would block every
        # producer forever and bury the error
        while True:
            if self.error is not None:
                raise RuntimeError("device worker failed") from self.error
            try:
                self.handoff.put(pb, timeout=0.05)
                return
            except queue.Full:
                continue

    def finish(self) -> List[Completion]:
        try:
            self.put(None)
        except RuntimeError:
            pass                        # worker already dead; join + raise
        self._thread.join()
        if self.error is not None:
            raise RuntimeError("device worker failed") from self.error
        return self.completions

    def _loop(self):
        try:
            while True:
                pb = self.handoff.get()
                if pb is None:
                    return
                dev = self.devices[self._n % len(self.devices)]
                self._n += 1
                rids = [r.rid for r in pb.requests]
                t0 = self.clock()
                comps = self.server.execute_prepared(pb, device=dev)
                t1 = self.clock()
                if self.metrics is not None:
                    self.metrics.on_device(rids, t0, t1)
                    self.metrics.on_complete([c.rid for c in comps], t1)
                self.completions.extend(comps)
                if self.on_complete is not None:
                    for c in comps:
                        self.on_complete(c)
                if self.on_drop is not None:
                    done = {c.rid for c in comps}
                    for rid in rids:
                        if rid not in done:    # MCT filter drop
                            self.on_drop(rid)
        except BaseException as e:          # surfaced by put()/finish()
            self.error = e


def run_pipelined(server: LMServer, groups: Sequence[Sequence[Request]], *,
                  pipeline_depth: int = 2, devices=None,
                  metrics: Optional[MetricsCollector] = None
                  ) -> List[Completion]:
    """Execute pre-formed batches through the two-stage pipeline.

    Batch composition is fixed by the caller (deterministic), so the result
    is bit-identical to running the groups synchronously — only the
    host/device overlap differs.
    """
    worker = _DeviceWorker(server, pipeline_depth, metrics, devices=devices)
    worker.start()
    for rs in groups:
        rs = list(rs)
        if not rs:
            continue
        t0 = time.perf_counter()
        pb = server.prepare_batch(rs)       # overlaps device execution
        t1 = time.perf_counter()
        if metrics is not None:
            metrics.on_encode([r.rid for r in rs], t0, t1)
        worker.put(pb)
    return worker.finish()


class AsyncScheduler:
    """Live continuous-batching front end with bounded admission.

    Thread layout: submitters call :meth:`submit`; a batcher thread drains
    the admission queue through a :class:`DeadlineAggregator` (wall-clock
    deadline), host-prepares one batch at a time, and hands it to the
    device worker through the depth-``pipeline_depth`` queue. Draining one
    batch per poll is what makes backpressure real — overload accumulates
    in the *bounded* admission queue instead of an unbounded internal
    buffer.
    """

    def __init__(self, server: LMServer,
                 config: Optional[SchedulerConfig] = None, *,
                 metrics: Optional[MetricsCollector] = None,
                 on_complete: Optional[Callable[[Completion], None]] = None,
                 **overrides):
        if config is None:
            config = SchedulerConfig(**overrides)
        elif overrides:
            raise ValueError("pass either config or keyword overrides")
        self.cfg = config
        self.server = server
        self.metrics = metrics if metrics is not None else MetricsCollector()
        self._epoch = time.perf_counter()
        self._lock = threading.Lock()
        self._have_work = threading.Condition(self._lock)
        self._space = threading.Condition(self._lock)
        self._pending: deque = deque()
        self._agg = DeadlineAggregator(target_batch=config.target_batch,
                                       deadline=config.deadline)
        self._closed = False
        self.n_submitted = 0
        self.n_rejected = 0
        self.n_shed = 0
        self._worker = _DeviceWorker(server, config.pipeline_depth,
                                     self.metrics, on_complete=on_complete,
                                     devices=config.devices,
                                     clock=self._now)
        self._batcher = threading.Thread(target=self._batch_loop, daemon=True)
        self._batcher_error: Optional[BaseException] = None
        self._started = False
        self._results: Optional[List[Completion]] = None

    # -- time ----------------------------------------------------------------
    def _now(self) -> float:
        return time.perf_counter() - self._epoch

    # completion/drop hooks (closed-loop generators chain onto these)
    @property
    def on_complete(self):
        return self._worker.on_complete

    @on_complete.setter
    def on_complete(self, cb):
        self._worker.on_complete = cb

    @property
    def on_drop(self):
        return self._worker.on_drop

    @on_drop.setter
    def on_drop(self, cb):
        self._worker.on_drop = cb

    # -- public API ------------------------------------------------------------
    def start(self) -> "AsyncScheduler":
        with self._lock:
            if self._started:
                return self
            self._started = True
        self._worker.start()
        self._batcher.start()
        return self

    def _depth_locked(self) -> int:
        return len(self._pending) + self._agg.pending()

    def _pipeline_dead(self) -> bool:
        return self._batcher_error is not None \
            or self._worker.error is not None

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return self._depth_locked()

    def submit(self, req: Request, *, arrival: Optional[float] = None) -> bool:
        """Offer a request; returns False when rejected by backpressure."""
        self.start()                 # idempotent, lock-guarded
        now = self._now()
        shed_rid: Optional[int] = None
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            if self.cfg.policy == "block":
                while self._depth_locked() >= self.cfg.max_queue \
                        and not self._closed and not self._pipeline_dead():
                    self._space.wait(timeout=0.1)
                if self._closed:
                    # close() raced our wait; the batcher may already have
                    # flushed and exited — appending now would lose the
                    # request silently
                    raise RuntimeError("scheduler is closed")
                if self._pipeline_dead():
                    # the batcher/device thread died, so queue space will
                    # never free up — fail fast instead of wedging the
                    # submitter (result() carries the root cause)
                    raise RuntimeError("scheduler pipeline failed; "
                                       "call result() for the cause")
            elif self._depth_locked() >= self.cfg.max_queue:
                if self.cfg.policy == "reject":
                    self.n_rejected += 1
                    self.metrics.on_reject(req.rid, now)
                    return False
                # shed_oldest: evict from the aggregator buffer first (the
                # overall oldest), then from the pending deque
                victim = self._agg.evict_oldest(now)
                if victim is None and self._pending:
                    victim = self._pending.popleft()
                if victim is not None:
                    self.n_shed += 1
                    self.metrics.on_shed(victim[1].rid, now)
                    shed_rid = victim[1].rid
            self._pending.append((req.rid, req))
            self.n_submitted += 1
            # arrival/admit recorded only once the request's fate is
            # decided — a submit that raised on a close() race must not
            # leave a phantom trace inflating the report
            self.metrics.on_arrival(req.rid, arrival if arrival is not None
                                    else now)
            self.metrics.on_admit(req.rid, now)
            self.metrics.note_queue_depth(self._depth_locked())
            self._have_work.notify()
        # user callback outside the non-reentrant lock: an on_drop that
        # reads queue_depth or re-submits must not deadlock (the device
        # thread already calls it unlocked — same contract)
        if shed_rid is not None and self._worker.on_drop is not None:
            self._worker.on_drop(shed_rid)
        return True

    def close(self):
        """Stop accepting requests and flush everything still queued."""
        with self._lock:
            self._closed = True
            self._have_work.notify_all()
            self._space.notify_all()

    def result(self) -> List[Completion]:
        """close() if needed, wait for the pipeline to drain, and return
        all completions (in execution order)."""
        if self._results is not None:
            return self._results
        if not self._started:
            self.start()       # zero submissions: drain cleanly to []
        self.close()
        self._batcher.join()
        completions = self._worker.finish()     # raises on device error
        if self._batcher_error is not None:
            raise RuntimeError("batcher thread failed") \
                from self._batcher_error
        self._results = completions
        return self._results

    def report(self, *, offered_qps: Optional[float] = None):
        rep = self.metrics.report(offered_qps=offered_qps)
        rep.n_rejected = max(rep.n_rejected, self.n_rejected)
        rep.n_shed = max(rep.n_shed, self.n_shed)
        return rep

    # -- batcher thread --------------------------------------------------------
    def _take_batch(self) -> Optional[List[Request]]:
        """Block until one batch is ready (target size or deadline) or the
        scheduler is closed and drained. Returns None to stop."""
        with self._lock:
            while True:
                # move newly-submitted requests into the aggregator, then
                # drain at most ONE batch — overload stays in the bounded
                # admission state where backpressure can see it
                now = self._now()
                while self._pending:
                    rid, req = self._pending.popleft()
                    self._agg.add(rid, [req], now=now)
                batches = self._agg.poll(now, limit=1)
                if batches:
                    self._space.notify_all()
                    return [q for q in batches[0].queries]
                if self._closed:
                    batches = self._agg.flush()
                    if batches:
                        self._space.notify_all()
                        return [q for q in batches[0].queries]
                    return None
                # idle: sleep until a submit/close notification; partial
                # batch buffered: sleep just long enough to fire its
                # deadline flush (no busy-ticking in either case)
                nd = self._agg.next_deadline()
                timeout = None if nd is None \
                    else max(nd - self._now(), 1e-4)
                self._have_work.wait(timeout=timeout)

    def _batch_loop(self):
        try:
            while True:
                rs = self._take_batch()
                if rs is None:
                    return
                t0 = self._now()
                pb = self.server.prepare_batch(rs)
                t1 = self._now()
                self.metrics.on_encode([r.rid for r in rs], t0, t1)
                # blocks while `pipeline_depth` batches are already in
                # flight — that stall is what pushes overload back onto
                # the bounded admission queue
                self._worker.put(pb)
        except BaseException as e:          # surfaced by result()
            self._batcher_error = e
            with self._lock:
                # blocked submitters must not wait for space that will
                # never free up
                self._space.notify_all()
                self._have_work.notify_all()

"""Unified serving front end: ``ServeConfig`` + ``build()`` -> ``Server``.

One dataclass describes the whole serving stack — model, replica topology,
batching, admission control — and one call wires it:

    from repro.serve import ServeConfig, build

    srv = build(ServeConfig(model="llama3.2-3b", max_seq=48,
                            replicas=2, target_batch=8, deadline=0.01))
    outs = srv.serve(requests, mode="pipelined")     # deterministic replay
    sched = srv.session()                            # live async serving
    sched.submit(req); ...; sched.result()

Or, for the whole build/run/teardown cycle in one call::

    outs, report = serve(requests, replicas=2, cache=True)

``ServeConfig`` + ``build()`` + ``Server.serve()``/``session()`` (and the
:func:`serve` convenience over them) are the *only* serving entry points —
the PR-1/PR-2 era ``run_pipelined``/``LMServer.serve_stream`` shims have
been removed. Optional subsystems all switch on the same way
(``cache=``/``capacity=``/``trace=`` accept None/bool/dict/config — see
:mod:`repro.serve.config`).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.serve.cache import (CacheConfig, CachedResult, NegativeResult,
                               ResultCache, request_key)
from repro.serve.capacity import CapacityConfig
from repro.serve.engine import (Completion, LMServer, Request,
                                form_batch_groups)
from repro.serve.group import EngineGroup, RoutingPolicy
from repro.serve.metrics import MetricsCollector, RunReport
from repro.serve.scheduler import (AsyncScheduler, BackpressurePolicy,
                                   SchedulerConfig)
from repro.serve.trace import TraceConfig, Tracer, TraceReport


@dataclass
class ServeConfig:
    """Everything needed to stand up a (possibly sharded) serving stack.

    Model / engine:
      ``model``       — architecture id (``repro.configs``) or a
                        ``ModelConfig`` instance.
      ``reduced``     — apply ``ModelConfig.reduced()`` (CPU-sized) first.
      ``server_factory`` — optional ``idx -> engine`` override; when set,
                        ``model``/``max_seq``/... are ignored and one
                        engine is built per replica (simulation, tests).
      ``warmup``      — batch-size buckets to pre-compile at build time
                        (``True`` = engine default; ``False`` = skip).

    Replica topology (first non-default wins: mesh > devices > replicas):
      ``mesh``/``mesh_axis`` — one replica per mesh slice along the axis
                        (see ``repro.sharding.specs.replica_device_groups``).
      ``devices``     — one replica pinned per listed jax device.
      ``replicas``    — N colocated replicas sharing the default device.
      ``routing``     — ``least_loaded`` (default), ``sticky``, or
                        ``hit_aware`` (cache-ownership affinity guarded by
                        ``spill_threshold``/``straggler_factor``/
                        ``ewma_alpha`` — see
                        :class:`~repro.serve.group.RoutingPolicy`).
      ``delay``       — optional ``repro.ft.failures.DelayInjector`` applied
                        per replica (straggler studies).

    Batching / admission (the AsyncScheduler knobs):
      ``target_batch``, ``deadline``, ``max_queue``, ``policy``
      (:class:`BackpressurePolicy` or its string value), ``pipeline_depth``.

    Result caching (off by default — the stack is bit-identical to its
    uncached behavior when ``cache`` is None):
      ``cache``       — ``CacheConfig`` (or ``True`` for defaults / a
                        kwargs dict) enabling the content-addressed
                        result cache + in-flight coalescing; one
                        :class:`~repro.serve.cache.ResultCache` instance
                        is shared by every replica, ``serve()`` call, and
                        live session of the built ``Server``.

    Capacity control (off by default — same bit-identity guarantee):
      ``capacity``    — ``CapacityConfig`` (or ``True`` for defaults / a
                        kwargs dict) attaching a
                        :class:`~repro.serve.capacity.CapacityController`
                        to every live session: online bottleneck
                        diagnosis + adaptive batch-target / replica-set /
                        admission-limit control.

    Tracing (off by default — same bit-identity guarantee):
      ``trace``       — ``TraceConfig`` (or ``True`` for defaults / a
                        kwargs dict) recording per-request lifecycle
                        spans into one shared
                        :class:`~repro.serve.trace.Tracer` (bounded ring
                        buffer); read back via :meth:`Server.trace_report`
                        / :meth:`Server.export_trace`.
    """
    model: Union[str, object] = "llama3.2-3b"
    reduced: bool = True
    max_seq: int = 64
    seed: int = 0
    rule_filter: object = None
    pad_batches: bool = True
    server_factory: Optional[Callable[[int], object]] = None
    # warm these batch-size buckets at build time (True = engine default;
    # engines without a warmup method, e.g. SimServer, ignore it)
    warmup: Union[bool, Sequence[int]] = False
    # replica topology
    replicas: int = 1
    devices: Optional[Sequence] = None
    mesh: object = None
    mesh_axis: str = "data"
    routing: Union[str, RoutingPolicy] = RoutingPolicy.LEAST_LOADED
    delay: object = None
    # hit_aware guard knobs (inert under other routing policies)
    spill_threshold: int = 96
    straggler_factor: float = 2.0
    ewma_alpha: float = 0.25
    # batching / admission
    target_batch: int = 8
    deadline: float = 0.05
    max_queue: int = 64
    policy: Union[str, BackpressurePolicy] = BackpressurePolicy.REJECT
    pipeline_depth: int = 2
    # result cache + coalescing (None/False = off, True = defaults,
    # dict/CacheConfig = explicit knobs)
    cache: Union[None, bool, dict, CacheConfig] = None
    # capacity control loop (None/False = off, True = defaults,
    # dict/CapacityConfig = explicit knobs)
    capacity: Union[None, bool, dict, CapacityConfig] = None
    # per-request tracing (None/False = off, True = defaults,
    # dict/TraceConfig = explicit knobs)
    trace: Union[None, bool, dict, TraceConfig] = None

    def __post_init__(self):
        # one shared coercion rule for every optional subsystem
        # (repro.serve.config.coerce)
        self.cache = CacheConfig.coerce(self.cache)
        self.capacity = CapacityConfig.coerce(self.capacity)
        self.trace = TraceConfig.coerce(self.trace)

    def scheduler_config(self, **overrides) -> SchedulerConfig:
        base = dict(target_batch=self.target_batch, deadline=self.deadline,
                    max_queue=self.max_queue, policy=self.policy,
                    pipeline_depth=self.pipeline_depth,
                    routing=self.routing,
                    spill_threshold=self.spill_threshold,
                    straggler_factor=self.straggler_factor,
                    ewma_alpha=self.ewma_alpha, cache=self.cache,
                    capacity=self.capacity, trace=self.trace)
        base.update(overrides)
        return SchedulerConfig(**base)


class Server:
    """Facade over an :class:`EngineGroup`: deterministic stream serving
    (:meth:`serve`) and live async sessions (:meth:`session`/:meth:`submit`)
    share the replicas, the routing policy, and one ``MetricsCollector``."""

    def __init__(self, group: EngineGroup, cfg: ServeConfig,
                 metrics: Optional[MetricsCollector] = None):
        self.group = group
        self.cfg = cfg
        self.metrics = metrics if metrics is not None else MetricsCollector()
        self._session: Optional[AsyncScheduler] = None
        # one ResultCache for the whole server: every serve() call, live
        # session, and replica shares it, so a result computed anywhere
        # serves hits everywhere
        self.cache: Optional[ResultCache] = \
            ResultCache(cfg.cache) if cfg.cache is not None else None
        # likewise one Tracer: serve() replays, live sessions, replica
        # workers, the cache, and the capacity controller all emit onto
        # the same timeline
        self.tracer: Optional[Tracer] = \
            Tracer(cfg.trace) if cfg.trace is not None else None

    # -- engine access --------------------------------------------------------
    @property
    def engine(self):
        """Replica 0's engine (capacity probes, direct generate_batch)."""
        return self.group.replicas[0].server

    @property
    def engines(self) -> List[object]:
        """Distinct engines across replicas (shared engines deduplicated)."""
        seen, out = set(), []
        for rep in self.group.replicas:
            if id(rep.server) not in seen:
                seen.add(id(rep.server))
                out.append(rep.server)
        return out

    def warmup(self, batch_sizes: Sequence[int] = (1, 8), **kw) -> None:
        """Pre-compile decode buckets on every distinct engine (no-op for
        engines without a ``warmup``, e.g. ``SimServer``)."""
        for eng in self.engines:
            fn = getattr(eng, "warmup", None)
            if fn is not None:
                fn(batch_sizes, **kw)

    # -- deterministic stream serving -----------------------------------------
    def serve(self, requests: Sequence[Request], *,
              mode: str = "pipelined") -> List[Completion]:
        """Serve an arrival-ordered request stream, deterministically.

        Batch composition is fixed by logical-time replay of the paper's
        deadline policy (``form_batch_groups``), so both modes run the
        exact same batch sequence:

        - ``mode="sync"``      — the baseline: prepare and execute strictly
          alternate on replica 0; the device idles during every host
          encode.
        - ``mode="pipelined"`` — batches are routed across all replicas,
          each with its own depth-``pipeline_depth`` host/device pipeline.

        **Bit-identity guarantee:** every replica serves the same model
        (same params), rows of a batch are independent (masked attention,
        power-of-two padding), and batch composition does not depend on
        wall-clock timing — so for any replica count and either routing
        policy (use ``sticky`` when the *placement* must also replay
        deterministically), ``mode="pipelined"`` returns completions
        bit-identical to ``mode="sync"``. Only throughput differs.

        With tracing configured (``ServeConfig.trace``), encode /
        dispatch / device-execute spans and completion/drop marks land in
        the server's shared :class:`~repro.serve.trace.Tracer` (submit-
        side stages only exist in live sessions, so a replayed stream has
        no queue-wait spans).

        With a result cache configured (``ServeConfig.cache``), a
        content-addressed pre-pass runs over the stream first: requests
        whose key is already cached are served without executing
        (``cache_hit``), later duplicates of an uncached key ride on the
        first occurrence (``coalesced``), and only the remaining unique
        leaders flow through the batch pipeline. TTL is judged against
        each request's *logical* arrival time, so a seeded stream always
        replays the same hit/miss/eviction sequence — and because minted
        completions carry the leader's exact tokens, the cached run stays
        bit-identical per rid to the uncached one.
        """
        if mode not in ("pipelined", "sync"):
            raise ValueError(
                f"mode must be 'pipelined' or 'sync', got {mode!r}")
        if self.cache is None:
            return self._execute_stream(requests, mode)
        return self._serve_cached(requests, mode)

    def _execute_stream(self, requests: Sequence[Request],
                        mode: str) -> List[Completion]:
        """The uncached replay path (exactly PR 2's ``serve`` body)."""
        groups = form_batch_groups(requests,
                                   target_batch=self.cfg.target_batch,
                                   deadline=self.cfg.deadline)
        if mode == "pipelined":
            return self.group.run_groups(
                groups, pipeline_depth=self.cfg.pipeline_depth,
                metrics=self.metrics, tracer=self.tracer,
                cache=self.cache)
        eng = self.engine
        out: List[Completion] = []
        for rs in groups:
            te0 = time.perf_counter()
            pb = eng.prepare_batch(rs)
            te1 = time.perf_counter()
            comps = eng.execute_prepared(pb)
            td1 = time.perf_counter()
            rids = [r.rid for r in rs]
            self.metrics.on_encode(rids, te0, te1)
            self.metrics.on_device(rids, te1, td1, replica=0)
            self.metrics.on_complete([c.rid for c in comps], td1)
            if self.tracer is not None:
                self.tracer.span("encode", te0, te1, rids=rids)
                self.tracer.span("device_execute", te1, td1, replica=0,
                                 rids=rids)
                done = {c.rid for c in comps}
                for c in comps:
                    self.tracer.mark("complete", td1, rid=c.rid, replica=0)
                for rid in rids:
                    if rid not in done:            # MCT filter drop
                        self.tracer.mark("drop", td1, rid=rid, replica=0,
                                         reason="filtered")
            out.extend(comps)
        return out

    def _serve_cached(self, requests: Sequence[Request],
                      mode: str) -> List[Completion]:
        """Content-addressed pre-pass + leader execution + cache fill.

        The cache clock is the stream's logical arrival time (TTL replays
        deterministically); metrics timestamps stay on the wall clock the
        rest of the replay path uses.
        """
        coalesce = self.cache.cfg.coalesce
        ttl = self.cache.cfg.ttl
        hits: List = []                       # (req, entry) pairs
        leaders: List[Request] = []
        key_of: Dict[int, str] = {}           # leader rid -> content key
        # key -> (leader rid, leader arrival) for this stream; a later
        # duplicate only coalesces if its logical gap to the leader is
        # within TTL — past that, the leader's result would already be
        # stale, so the duplicate becomes a fresh leader
        stream_leader: Dict[str, tuple] = {}
        followers: Dict[int, List[Request]] = {}
        for r in sorted(requests, key=lambda q: q.arrival):
            key = request_key(r)
            entry = self.cache.get(key, r.arrival, metrics=self.metrics)
            if isinstance(entry, NegativeResult):
                # content is known-filtered (negative cache): drop it
                # without encoding or executing, like the engine would
                self.metrics.on_cache("negative_hits")
                if self.tracer is not None:
                    t = time.perf_counter()
                    self.tracer.mark("cache_lookup", t, rid=r.rid,
                                     outcome="negative_hit")
                    self.tracer.mark("negative_drop", t, rid=r.rid)
                continue
            if entry is not None:
                hits.append((r, entry))
                t = time.perf_counter()
                self.metrics.on_cache_hit(r.rid, t, replica=entry.replica)
                self.metrics.on_complete([r.rid], t)
                if self.tracer is not None:
                    self.tracer.mark("cache_lookup", t, rid=r.rid,
                                     outcome="hit")
                    self.tracer.mark("complete", t, rid=r.rid,
                                     source="cache")
                continue
            lead = stream_leader.get(key) if coalesce else None
            if lead is not None and (ttl is None
                                     or r.arrival - lead[1] <= ttl):
                followers.setdefault(lead[0], []).append(r)
                t = time.perf_counter()
                self.metrics.on_coalesce(r.rid, lead[0], t)
                if self.tracer is not None:
                    self.tracer.mark("coalesce", t, rid=r.rid,
                                     leader=lead[0])
                continue
            stream_leader[key] = (r.rid, r.arrival)
            key_of[r.rid] = key
            leaders.append(r)
            self.metrics.on_cache_miss(r.rid)
            if self.tracer is not None:
                self.tracer.mark("cache_lookup", time.perf_counter(),
                                 rid=r.rid, outcome="miss")
        comps = self._execute_stream(leaders, mode) if leaders else []
        done = {c.rid: c for c in comps}
        out: List[Completion] = list(comps)
        for r in leaders:
            c = done.get(r.rid)
            foll = followers.get(r.rid, [])
            if c is None:
                # leader was filtered out (MCT): its followers drop with
                # it, and the verdict is remembered (negative_ttl) so the
                # same doomed content skips execution on its next arrival
                if foll:
                    self.metrics.on_cache("follower_drops", len(foll))
                    if self.tracer is not None:
                        t = time.perf_counter()
                        for f in foll:
                            self.tracer.mark("follower_drop", t,
                                             rid=f.rid, leader=r.rid)
                self.cache.put_negative(key_of[r.rid], r.arrival,
                                        metrics=self.metrics)
                continue
            entry = CachedResult.of(
                c, replica=self.metrics.replica_of(c.rid), now=r.arrival)
            self.cache.put(key_of[r.rid], entry, metrics=self.metrics)
            t = time.perf_counter()
            for f in foll:
                out.append(entry.mint(f.rid))
                self.metrics.on_complete([f.rid], t)
                if self.tracer is not None:
                    self.tracer.mark("complete", t, rid=f.rid,
                                     source="coalesce")
        out.extend(entry.mint(r.rid) for r, entry in hits)
        self.metrics.note_cache_bytes(self.cache.bytes_resident,
                                      len(self.cache))
        return out

    # -- live async serving ----------------------------------------------------
    def session(self, *, metrics: Optional[MetricsCollector] = None,
                **overrides) -> AsyncScheduler:
        """A fresh live serving session (bounded admission + backpressure)
        over the shared replicas. ``overrides`` patch the scheduler knobs
        for this session only (e.g. ``policy="block"``)."""
        return AsyncScheduler(
            self.group, self.cfg.scheduler_config(**overrides),
            metrics=metrics if metrics is not None else MetricsCollector(),
            cache=self.cache, tracer=self.tracer)

    def submit(self, req: Request, **kw) -> bool:
        """Submit to the server's default live session (created lazily,
        sharing ``self.metrics``); drain with :meth:`result`."""
        if self._session is None:
            self._session = AsyncScheduler(
                self.group, self.cfg.scheduler_config(),
                metrics=self.metrics, cache=self.cache,
                tracer=self.tracer)
        return self._session.submit(req, **kw)

    def result(self) -> List[Completion]:
        if self._session is None:
            return []
        out = self._session.result()
        self._session = None        # sessions are one-shot; allow another
        return out

    def close(self) -> None:
        """Reap the default session's pipeline threads (idempotent,
        swallows pipeline errors — use :meth:`result` to surface them).
        Safe to call with no session open."""
        s, self._session = self._session, None
        if s is not None:
            s.shutdown()

    def __enter__(self) -> "Server":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        # whether the body raised or not, never leak the pipeline thread
        self.close()
        return False

    def report(self, *, offered_qps: Optional[float] = None) -> RunReport:
        if self.cache is not None:
            self.metrics.note_cache_bytes(self.cache.bytes_resident,
                                          len(self.cache))
        return self.metrics.report(offered_qps=offered_qps)

    # -- tracing ---------------------------------------------------------------
    def trace_report(self) -> Optional[TraceReport]:
        """Per-stage latency percentiles + per-replica straggler
        attribution derived from the shared tracer's spans; None when
        ``ServeConfig.trace`` is off."""
        return self.tracer.report() if self.tracer is not None else None

    def export_trace(self, path: str, *, fmt: str = "chrome") -> str:
        """Write the recorded spans: ``fmt="chrome"`` (load the file in
        ``chrome://tracing`` / Perfetto) or ``fmt="jsonl"`` (one span per
        line). Returns ``path``."""
        if self.tracer is None:
            raise RuntimeError(
                "tracing is off; enable with ServeConfig(trace=True)")
        if fmt == "chrome":
            return self.tracer.export_chrome(path)
        if fmt == "jsonl":
            return self.tracer.export_jsonl(path)
        raise ValueError(f"fmt must be 'chrome' or 'jsonl', got {fmt!r}")


def build(cfg: ServeConfig) -> Server:
    """Construct the full serving stack from one config: engines (or take
    them from ``cfg.server_factory``), the replica :class:`EngineGroup`,
    and the shared :class:`MetricsCollector`."""
    knobs = dict(spill_threshold=cfg.spill_threshold,
                 straggler_factor=cfg.straggler_factor,
                 ewma_alpha=cfg.ewma_alpha)
    if cfg.server_factory is not None:
        servers = [cfg.server_factory(i) for i in range(max(1, cfg.replicas))]
        group = EngineGroup.from_servers(servers, routing=cfg.routing,
                                         delay=cfg.delay, **knobs)
        srv = Server(group, cfg)
    else:
        model = cfg.model
        if isinstance(model, str):
            from repro.configs.base import get_config
            model = get_config(model)
        if cfg.reduced:
            model = model.reduced()
        server = LMServer(model, max_seq=cfg.max_seq, seed=cfg.seed,
                          rule_filter=cfg.rule_filter,
                          pad_batches=cfg.pad_batches)
        if cfg.mesh is not None:
            group = EngineGroup.from_mesh(server, cfg.mesh,
                                          axis=cfg.mesh_axis,
                                          routing=cfg.routing,
                                          delay=cfg.delay, **knobs)
        else:
            group = EngineGroup.from_server(server, devices=cfg.devices,
                                            replicas=cfg.replicas,
                                            routing=cfg.routing,
                                            delay=cfg.delay, **knobs)
        srv = Server(group, cfg)
    if cfg.warmup:
        srv.warmup() if cfg.warmup is True else srv.warmup(tuple(cfg.warmup))
    return srv


def serve(requests: Sequence[Request], *, mode: str = "pipelined",
          offered_qps: Optional[float] = None,
          config: Optional[ServeConfig] = None,
          **config_kwargs) -> Tuple[List[Completion], RunReport]:
    """One-call serving: build the stack, serve the stream, tear it down.

    Keyword arguments are :class:`ServeConfig` fields (or pass a prebuilt
    ``config``); the server is built, the requests are served in ``mode``
    (``"pipelined"``/``"sync"``), the pipeline threads are reaped via the
    context manager, and ``(completions, RunReport)`` is returned::

        outs, report = serve(reqs, model="llama3.2-3b", replicas=2,
                             cache=True, trace=True)

    This is the convenience layer over ``build(cfg)`` + ``Server.serve``;
    use those directly when you need live sessions, a shared server
    across calls, or trace exports (the built ``Server`` owns the
    tracer).
    """
    if config is None:
        config = ServeConfig(**config_kwargs)
    elif config_kwargs:
        raise ValueError("pass either config or keyword overrides")
    with build(config) as srv:
        outs = srv.serve(requests, mode=mode)
        report = srv.report(offered_qps=offered_qps)
    return outs, report

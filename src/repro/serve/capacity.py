"""Capacity subsystem: online bottleneck detection, adaptive host/device
balance control, and cost-efficiency reporting.

The paper's central deployment finding (§5–6, Tables 2–3) is that the
accelerator's gains evaporate — and the system can get *more expensive*
per query — when the deployment is imbalanced: a weak CPU cannot generate
enough load for a powerful accelerator, so the FPGA idles while the bill
keeps running. PR 2's replica sweep reproduced exactly that plateau
(throughput pinned at the serial-host prepare cap regardless of replica
count), but diagnosing and re-tuning was the operator's job. This module
closes the loop:

- :class:`BottleneckMonitor` — consumes the serving stack's metric
  signals (host-prepare rate, device-idle fraction, queue fill, cache hit
  rate) over sliding windows and classifies the run as **host-bound**,
  **device-bound**, **admission-bound**, or **balanced**. Hysteresis
  (``confirm`` consecutive windows before a switch) keeps the published
  diagnosis from flapping on noisy windows.
- :class:`CapacityController` — a control loop over an actuator (the
  :class:`~repro.serve.scheduler.AsyncScheduler` implements the protocol)
  that acts on the diagnosis: grows/shrinks the batch-bucket target,
  activates or parks replicas within a device budget, and adapts the
  admission limit with AIMD so offered load tracks the true bottleneck
  instead of the static queue bound. ``capacity=None`` (the default
  everywhere) wires nothing and leaves the serving stack bit-identical
  to its uncontrolled behavior.
- :class:`CostReport` — maps measured steady-state throughput through the
  deployment prices of :mod:`repro.core.cost_model` to $/1k-queries per
  (host, accelerator, replica-count) configuration — the paper's
  balanced-vs-imbalanced cost comparison, computed from *our* measured
  numbers rather than the paper's.

Use via config (``ServeConfig(capacity=...)`` / ``SchedulerConfig
(capacity=...)``) or standalone::

    from repro.capacity import BottleneckMonitor, CapacitySignals

    mon = BottleneckMonitor(confirm=2)
    for sig in windows:                    # CapacitySignals stream
        diagnosis = mon.observe(sig)
"""
from __future__ import annotations

import enum
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.cost_model import (aws_accel_usd_per_hour,
                                   aws_host_usd_per_hour,
                                   usd_per_1k_queries)
from repro.serve.config import Coercible
from repro.serve.metrics import SignalSnapshot


class Bottleneck(str, enum.Enum):
    """Where the serving stack's throughput is currently capped."""
    HOST_BOUND = "host_bound"            # serial host prepare saturated,
                                         # devices starved
    DEVICE_BOUND = "device_bound"        # accelerators saturated
    ADMISSION_BOUND = "admission_bound"  # queue bound rejects load while
                                         # host AND device have headroom
    BALANCED = "balanced"

    def __str__(self) -> str:            # StrEnum parity on py3.10
        return self.value


@dataclass(frozen=True)
class CapacitySignals:
    """One sliding window of serving signals — what the monitor consumes.

    Built from two cumulative :class:`~repro.serve.metrics.SignalSnapshot`
    totals (:meth:`between`) plus the scheduler's live admission state;
    tests construct instances directly to drive the classifier with
    synthetic streams.
    """
    t: float                      # window end (scheduler clock)
    window_s: float
    arrival_rate: float           # requests/s offered in the window
    completion_rate: float
    reject_rate: float            # rejects + sheds per second
    host_prepare_rate: float      # host-prepared batches per second
    host_busy_fraction: float     # encode time / window (serial host path)
    device_idle_fraction: float   # 1 - busy/(window * active replicas)
    queue_fill: float             # admission depth / admission limit
    cache_hit_rate: float         # (hits+coalesced)/tracked in the window
    n_active_replicas: int = 1
    replica_queue_depths: Tuple[int, ...] = ()

    @classmethod
    def between(cls, prev: SignalSnapshot, cur: SignalSnapshot, *,
                queue_depth: int, admission_limit: int,
                n_active_replicas: int = 1,
                replica_queue_depths: Sequence[int] = ()
                ) -> "CapacitySignals":
        """Window rates from two cumulative snapshots + live queue state."""
        w = max(cur.t - prev.t, 1e-9)
        d_hits = cur.cache_hits - prev.cache_hits
        d_miss = cur.cache_misses - prev.cache_misses
        d_coal = cur.cache_coalesced - prev.cache_coalesced
        tracked = d_hits + d_miss + d_coal
        n_active = max(1, n_active_replicas)
        busy = (cur.device_busy_s - prev.device_busy_s) / (w * n_active)
        return cls(
            t=cur.t, window_s=w,
            arrival_rate=(cur.n_arrivals - prev.n_arrivals) / w,
            completion_rate=(cur.n_completions - prev.n_completions) / w,
            reject_rate=(cur.n_rejected - prev.n_rejected
                         + cur.n_shed - prev.n_shed) / w,
            host_prepare_rate=(cur.n_encoded_batches
                               - prev.n_encoded_batches) / w,
            host_busy_fraction=min(
                1.0, (cur.encode_busy_s - prev.encode_busy_s) / w),
            device_idle_fraction=max(0.0, min(1.0, 1.0 - busy)),
            queue_fill=queue_depth / max(1, admission_limit),
            cache_hit_rate=(d_hits + d_coal) / tracked if tracked else 0.0,
            n_active_replicas=n_active,
            replica_queue_depths=tuple(replica_queue_depths),
        )


class BottleneckMonitor:
    """Sliding-window bottleneck classifier with hysteresis.

    :meth:`classify` is the stateless per-window rule; :meth:`observe`
    applies hysteresis — the published :attr:`diagnosis` only switches
    after ``confirm`` consecutive windows agree on a new label, so one
    noisy window can never flap the controller.
    """

    def __init__(self, *, idle_hi: float = 0.5, idle_lo: float = 0.15,
                 host_busy_hi: float = 0.6, queue_hi: float = 0.85,
                 confirm: int = 2):
        self.idle_hi = idle_hi
        self.idle_lo = idle_lo
        self.host_busy_hi = host_busy_hi
        self.queue_hi = queue_hi
        self.confirm = max(1, confirm)
        self.diagnosis = Bottleneck.BALANCED
        self.history: List[Tuple[float, Bottleneck]] = []   # published flips
        self._candidate: Optional[Bottleneck] = None
        self._streak = 0

    def classify(self, s: CapacitySignals) -> Bottleneck:
        """Raw single-window classification (no hysteresis)."""
        if s.arrival_rate <= 0 and s.queue_fill <= 0:
            return Bottleneck.BALANCED          # idle stack: nothing to fix
        pressured = s.queue_fill >= self.queue_hi or s.reject_rate > 0
        if s.host_busy_fraction >= self.host_busy_hi \
                and s.device_idle_fraction >= self.idle_hi:
            # host saturated while devices starve: the paper's weak-CPU /
            # strong-FPGA imbalance
            return Bottleneck.HOST_BOUND
        if s.device_idle_fraction <= self.idle_lo:
            return Bottleneck.DEVICE_BOUND
        if pressured and s.device_idle_fraction >= self.idle_hi:
            # queue bound binds while both sides have headroom: the static
            # admission limit, not the hardware, is refusing the load
            return Bottleneck.ADMISSION_BOUND
        return Bottleneck.BALANCED

    def observe(self, s: CapacitySignals) -> Bottleneck:
        """Feed one window; returns the (hysteresis-filtered) diagnosis."""
        raw = self.classify(s)
        if raw == self.diagnosis:
            self._candidate, self._streak = None, 0
        elif raw == self._candidate:
            self._streak += 1
            if self._streak >= self.confirm:
                self.diagnosis = raw
                self.history.append((s.t, raw))
                self._candidate, self._streak = None, 0
        else:
            self._candidate, self._streak = raw, 1
            if self.confirm <= 1:
                self.diagnosis = raw
                self.history.append((s.t, raw))
                self._candidate, self._streak = None, 0
        return self.diagnosis


@dataclass
class CapacityConfig(Coercible):
    """Knobs for the capacity control loop (attach to
    ``ServeConfig.capacity`` / ``SchedulerConfig.capacity``; ``None``
    keeps the subsystem fully unwired and the stack bit-identical to its
    uncontrolled behavior).

    Monitor:     ``window_s``, ``confirm``, ``idle_hi``, ``idle_lo``,
                 ``host_busy_hi``, ``queue_hi`` (see
                 :class:`BottleneckMonitor`).
    Batch:       target-batch bounds ``min_batch``/``max_batch`` —
                 host-bound runs grow the bucket target (amortising the
                 per-batch host cost), bounded by the compile buckets.
    Replicas:    ``min_replicas``/``max_replicas`` device budget;
                 ``initial_replicas`` parks down to a starting set so
                 device-bound runs can demonstrate activation.
    Admission:   AIMD on the admission limit — additive ``queue_ai`` per
                 window with headroom, multiplicative ``queue_md`` under
                 congestion, clamped to [``min_queue``, ``max_queue``].
    """
    window_s: float = 0.25
    confirm: int = 2
    idle_hi: float = 0.5
    idle_lo: float = 0.15
    host_busy_hi: float = 0.6
    queue_hi: float = 0.85
    min_batch: int = 2
    max_batch: int = 64
    min_replicas: int = 1
    max_replicas: Optional[int] = None     # None = every built replica
    initial_replicas: Optional[int] = None
    min_queue: int = 8
    max_queue: int = 256
    queue_ai: int = 8
    queue_md: float = 0.5


@dataclass(frozen=True)
class ControllerAction:
    """One control decision: what the controller changed and why."""
    t: float
    diagnosis: str
    action: str          # grow_batch / park_replica / activate_replica /
                         # queue_increase / queue_decrease
    before: float
    after: float

    def as_dict(self) -> Dict[str, object]:
        return {"t": self.t, "diagnosis": self.diagnosis,
                "action": self.action, "before": self.before,
                "after": self.after}


class CapacityController:
    """Adaptive host/device balance control loop.

    ``actuator`` is any object implementing the capacity protocol (the
    :class:`~repro.serve.scheduler.AsyncScheduler` does):

    - ``capacity_state() -> dict`` with ``queue_depth``,
      ``admission_limit``, ``target_batch``, ``n_active``, ``n_replicas``,
      ``replica_depths``
    - ``set_target_batch(n)`` / ``set_admission_limit(n)`` /
      ``set_active_replicas(n)``

    Policy per published diagnosis:

    - **host-bound** — double the batch-bucket target (amortise the
      per-batch host cost over more requests) up to ``max_batch``; once
      maxed, park an idle replica (devices are starving anyway — parked
      replicas stop costing money in the :class:`CostReport`) and, under
      queue congestion, multiplicatively shrink the admission limit so
      queue wait stops masquerading as capacity.
    - **device-bound** — activate a parked replica within the device
      budget; at budget, grow the batch target (amortise per-batch device
      overhead), then AIMD-shrink admission under congestion: the system
      is genuinely full.
    - **admission-bound** — the static queue bound is the limiter while
      both sides have headroom: additively raise the admission limit.
    - **balanced** — gentle additive probe of the admission limit when
      the queue is working (> half full), otherwise no-op.

    :meth:`tick` is one synchronous control step (tests drive it
    directly); :meth:`start` runs ticks on a daemon thread every
    ``window_s``. A controller exception never kills the serving
    pipeline — it is recorded on :attr:`error` and the loop stops.
    """

    def __init__(self, actuator, config=None, *, metrics=None, clock=None,
                 tracer=None):
        self.cfg = CapacityConfig.coerce(config) or CapacityConfig()
        self.actuator = actuator
        self.metrics = metrics
        self.tracer = tracer            # controller actions as trace events
        self.clock = clock if clock is not None else time.perf_counter
        self.monitor = BottleneckMonitor(
            idle_hi=self.cfg.idle_hi, idle_lo=self.cfg.idle_lo,
            host_busy_hi=self.cfg.host_busy_hi, queue_hi=self.cfg.queue_hi,
            confirm=self.cfg.confirm)
        self.actions: List[ControllerAction] = []
        self.error: Optional[BaseException] = None
        self._prev: Optional[SignalSnapshot] = None
        # (t, n_active) timeline for the time-weighted mean the cost
        # report charges for
        self._active_log: List[Tuple[float, int]] = []
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "CapacityController":
        if self._thread is None:
            if self.cfg.initial_replicas is not None:
                self._set_active(self.cfg.initial_replicas, self.clock(),
                                 "initial", log=False)
            self._active_log.append(
                (self.clock(), self.actuator.capacity_state()["n_active"]))
            self._thread = threading.Thread(target=self._loop, daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()

    def _loop(self):
        while not self._stop.wait(self.cfg.window_s):
            try:
                self.tick()
            except BaseException as e:      # never kill the pipeline
                self.error = e
                return

    # -- one control step ----------------------------------------------------
    def tick(self, now: Optional[float] = None) -> Optional[Bottleneck]:
        """Snapshot -> window signals -> diagnosis -> actions. Returns the
        published diagnosis (None on the priming tick)."""
        now = self.clock() if now is None else now
        snap = self.metrics.snapshot(now)
        state = self.actuator.capacity_state()
        prev, self._prev = self._prev, snap
        if prev is None:
            return None                     # priming: need two snapshots
        sig = CapacitySignals.between(
            prev, snap,
            queue_depth=state["queue_depth"],
            admission_limit=state["admission_limit"],
            n_active_replicas=state["n_active"],
            replica_queue_depths=state.get("replica_depths", ()))
        diag = self.monitor.observe(sig)
        self._act(diag, sig, state, now)
        return diag

    def _budget(self, state) -> int:
        n = state["n_replicas"]
        return min(self.cfg.max_replicas or n, n)

    def _act(self, diag: Bottleneck, sig: CapacitySignals, state, now):
        tb = state["target_batch"]
        lim = state["admission_limit"]
        n_active = state["n_active"]
        congested = sig.queue_fill >= 0.9
        if diag == Bottleneck.HOST_BOUND:
            if tb < self.cfg.max_batch:
                self._set_batch(min(self.cfg.max_batch, tb * 2), now, diag)
            else:
                if n_active > self.cfg.min_replicas \
                        and sig.device_idle_fraction >= self.cfg.idle_hi:
                    self._set_active(n_active - 1, now, diag)
                if congested and lim > self.cfg.min_queue:
                    self._set_limit(
                        max(self.cfg.min_queue,
                            int(lim * self.cfg.queue_md)), now, diag)
        elif diag == Bottleneck.DEVICE_BOUND:
            if n_active < self._budget(state):
                self._set_active(n_active + 1, now, diag)
            elif tb < self.cfg.max_batch and congested:
                self._set_batch(min(self.cfg.max_batch, tb * 2), now, diag)
            elif congested and lim > self.cfg.min_queue:
                self._set_limit(
                    max(self.cfg.min_queue,
                        int(lim * self.cfg.queue_md)), now, diag)
        elif diag == Bottleneck.ADMISSION_BOUND:
            if lim < self.cfg.max_queue:
                self._set_limit(min(self.cfg.max_queue,
                                    lim + self.cfg.queue_ai), now, diag)
        else:   # BALANCED: probe the admission limit upward when in use
            if sig.queue_fill >= 0.5 and lim < self.cfg.max_queue:
                self._set_limit(min(self.cfg.max_queue,
                                    lim + self.cfg.queue_ai), now, diag)

    # -- actuation + logging -------------------------------------------------
    def _log(self, t, diag, action, before, after):
        a = ControllerAction(t=t, diagnosis=str(diag), action=action,
                             before=float(before), after=float(after))
        self.actions.append(a)
        if self.metrics is not None:
            self.metrics.on_capacity(a.as_dict())
        if self.tracer is not None:
            # batch-target doubling / replica parking shows up on the
            # same timeline as the requests it affects
            self.tracer.mark("controller", t, action=action,
                             diagnosis=str(diag), before=float(before),
                             after=float(after))

    def _set_batch(self, n, now, diag):
        before = self.actuator.capacity_state()["target_batch"]
        n = max(self.cfg.min_batch, min(self.cfg.max_batch, int(n)))
        if n == before:
            return
        self.actuator.set_target_batch(n)
        self._log(now, diag, "grow_batch" if n > before else "shrink_batch",
                  before, n)

    def _set_limit(self, n, now, diag):
        before = self.actuator.capacity_state()["admission_limit"]
        n = max(self.cfg.min_queue, min(self.cfg.max_queue, int(n)))
        if n == before:
            return
        self.actuator.set_admission_limit(n)
        self._log(now, diag, "queue_increase" if n > before
                  else "queue_decrease", before, n)

    def _set_active(self, n, now, diag, *, log=True):
        state = self.actuator.capacity_state()
        before = state["n_active"]
        n = max(self.cfg.min_replicas, min(self._budget(state), int(n)))
        if n == before:
            return
        self.actuator.set_active_replicas(n)
        self._active_log.append((now, n))
        if log:
            self._log(now, diag, "activate_replica" if n > before
                      else "park_replica", before, n)

    # -- reporting -----------------------------------------------------------
    def mean_active_replicas(self, now: Optional[float] = None) -> float:
        """Time-weighted mean of the active replica count — what the cost
        report charges for (a parked replica could be serving another
        tenant / powered down)."""
        if not self._active_log:
            return float(self.actuator.capacity_state()["n_active"])
        now = self.clock() if now is None else now
        total = weight = 0.0
        for (t0, n), (t1, _) in zip(self._active_log,
                                    self._active_log[1:]
                                    + [(now, self._active_log[-1][1])]):
            dt = max(0.0, t1 - t0)
            total += n * dt
            weight += dt
        return total / weight if weight > 0 else float(
            self._active_log[-1][1])

    def summary(self) -> Dict[str, object]:
        state = self.actuator.capacity_state()
        return {
            "diagnosis": str(self.monitor.diagnosis),
            "history": [(t, str(d)) for t, d in self.monitor.history],
            "n_actions": len(self.actions),
            "final": {"target_batch": state["target_batch"],
                      "admission_limit": state["admission_limit"],
                      "n_active": state["n_active"]},
            "mean_active_replicas": self.mean_active_replicas(),
            "error": repr(self.error) if self.error is not None else None,
        }


# ---------------------------------------------------------------------------
# Cost-efficiency reporting ($/1k-queries through the paper's unit prices)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BoxPrice:
    """$/hour prices for one (host, accelerator) box family."""
    name: str
    host_usd_per_hour: float
    accel_usd_per_hour: float       # per active accelerator replica

    def usd_per_hour(self, replicas: float) -> float:
        return self.host_usd_per_hour + replicas * self.accel_usd_per_hour


# the paper's Table 2 cloud unit prices, pro-rated per box: a weak 8-vCPU
# f1-style host vs a 48-vCPU c5-style host, each feeding N accelerator
# replicas priced at the f1.2xlarge's accelerator share
PAPER_BOXES: Dict[str, BoxPrice] = {
    "weak_host": BoxPrice("8-vCPU host + FPGA replicas",
                          aws_host_usd_per_hour(8), aws_accel_usd_per_hour()),
    "balanced": BoxPrice("48-vCPU host + FPGA replicas",
                         aws_host_usd_per_hour(48), aws_accel_usd_per_hour()),
}


@dataclass(frozen=True)
class CostRow:
    """One measured configuration priced out."""
    config: str
    host: str
    replicas: float               # time-weighted mean active replicas
    achieved_qps: float
    usd_per_hour: float
    usd_per_1k: float

    def as_dict(self) -> Dict[str, object]:
        return {"config": self.config, "host": self.host,
                "replicas": self.replicas,
                "achieved_qps": self.achieved_qps,
                "usd_per_hour": self.usd_per_hour,
                "usd_per_1k_queries": self.usd_per_1k}


@dataclass
class CostReport:
    """Measured throughput -> $/1k-queries per configuration.

    ``add(...)`` one row per (host profile, replica count) measurement;
    prices come from a :class:`BoxPrice` (default: the paper-derived
    :data:`PAPER_BOXES`). The resulting table is the paper's Tables 2–3
    argument — a weak host feeding many accelerators can cost *more* per
    query than a balanced box — computed from our own steady-state
    numbers.
    """
    rows: List[CostRow] = field(default_factory=list)

    def add(self, config: str, *, host: str, replicas: float,
            achieved_qps: float,
            price: Optional[BoxPrice] = None) -> CostRow:
        price = price if price is not None else PAPER_BOXES[host]
        usd_h = price.usd_per_hour(replicas)
        row = CostRow(config=config, host=host, replicas=float(replicas),
                      achieved_qps=float(achieved_qps), usd_per_hour=usd_h,
                      usd_per_1k=usd_per_1k_queries(usd_h, achieved_qps))
        self.rows.append(row)
        return row

    def best(self) -> Optional[CostRow]:
        return min(self.rows, key=lambda r: r.usd_per_1k, default=None)

    def as_dict(self) -> Dict[str, object]:
        best = self.best()
        return {"rows": [r.as_dict() for r in self.rows],
                "best": best.as_dict() if best is not None else None}

    def table(self) -> str:
        """Markdown table (README / benchmark logs)."""
        lines = ["| config | host | replicas | qps | $/h | $/1k queries |",
                 "|---|---|---|---|---|---|"]
        for r in sorted(self.rows, key=lambda r: r.usd_per_1k):
            lines.append(
                f"| {r.config} | {r.host} | {r.replicas:.2f} "
                f"| {r.achieved_qps:.0f} | {r.usd_per_hour:.3f} "
                f"| {r.usd_per_1k:.5f} |")
        return "\n".join(lines)

"""Sharded multi-replica serving: one engine replica per device (or mesh
slice), a single admission path, replica-aware batch routing.

The paper's imbalance finding (§5–6) — a powerful accelerator starved by a
host that cannot generate enough load — only becomes visible at scale when
several accelerators share one admission path. ``EngineGroup`` is that
integration layer: it owns one ``LMServer`` replica per device (or per mesh
slice via :func:`repro.sharding.specs.replica_device_groups`), and a
``GroupRun`` gives every replica its own depth-``pipeline_depth``
host-encode/device-execute pipeline, so host work for replica A overlaps
device work on replica B. The single dispatcher thread is the deliberately
serial host path whose saturation produces the CPU-bound plateau the fig13
replica sweep measures.

Routing (:class:`RoutingPolicy`):

- ``least_loaded`` — route to the replica with the minimum outstanding work
  (prefill + decode tokens of every batch in its pipeline), round-robin
  among ties. A slow or stalled replica accumulates outstanding work and
  stops attracting traffic, so it cannot wedge the shared admission queue.
- ``sticky``       — batch goes to replica ``min(rid) % n_replicas``:
  replica assignment depends only on batch content, never on timing, which
  makes multi-replica runs deterministically replayable (and, since every
  replica computes the same function, bit-identical to the single-replica
  synchronous baseline).
- ``hit_aware``    — cache-ownership affinity with a straggler guard: when
  the shared :class:`~repro.serve.cache.ResultCache` knows which replica
  produced a batch's content (live entry or the tombstone a TTL expiry
  leaves behind), prefer that replica — its device-side state for the
  content is still warm, so the recompute is cheaper there. The preference
  is *guarded*: if the owner's batch-latency EWMA marks it a straggler
  (``straggler_factor``× the other active replicas' mean) or its
  outstanding-work gap over the least-loaded candidate exceeds
  ``spill_threshold``, the batch spills to the least-loaded healthy
  replica and the content is re-homed there. Without a cache (or with no
  hints for the batch), decisions are identical to ``least_loaded``.
"""
from __future__ import annotations

import enum
import queue
import threading
import time
from collections import Counter
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

from repro.serve.config import coerce_enum
from repro.serve.engine import Completion


class RoutingPolicy(str, enum.Enum):
    """How the dispatcher picks a replica for the next prepared batch."""
    LEAST_LOADED = "least_loaded"
    STICKY = "sticky"
    HIT_AWARE = "hit_aware"

    def __str__(self) -> str:            # StrEnum parity on py3.10
        return self.value


ROUTING_POLICIES = tuple(p.value for p in RoutingPolicy)


def batch_work(requests) -> int:
    """Outstanding-work estimate of a batch: prefill tokens plus decode
    steps. The decode loop runs to the batch max for every row, so decode
    cost is ``B * max_new``, which is what makes skewed per-request decode
    lengths matter for routing."""
    rs = list(requests)
    if not rs:
        return 0
    max_new = max(r.max_new_tokens for r in rs)
    return sum(len(r.tokens) + max_new for r in rs)


@dataclass
class Replica:
    """One serving replica: an engine plus the devices it executes on
    (``None`` = jax default device; several = round-robin within the
    replica)."""
    idx: int
    server: object
    devices: Optional[Sequence] = None


class _ReplicaWorker:
    """Device half of one replica's pipeline: consumes prepared batches
    from the replica's own bounded handoff queue, executes them on the
    replica's device(s), records per-replica busy intervals."""

    def __init__(self, replica: Replica, depth: int, metrics,
                 on_complete: Optional[Callable[[Completion], None]] = None,
                 on_drop: Optional[Callable[[int], None]] = None,
                 clock=time.perf_counter, delay=None,
                 on_batch_done: Optional[
                     Callable[[int, int, float], None]] = None,
                 tracer=None):
        self.replica = replica
        self.handoff: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
        self.metrics = metrics
        self.tracer = tracer
        self.on_complete = on_complete
        self.on_drop = on_drop          # rid sinks without a Completion
        self.clock = clock
        self.delay = delay              # repro.ft.failures.DelayInjector
        self.on_batch_done = on_batch_done
        self.devices = list(replica.devices) if replica.devices else [None]
        self.completions: List[Completion] = []
        self.error: Optional[BaseException] = None
        self._n = 0
        self._thread = threading.Thread(target=self._loop, daemon=True)

    def start(self):
        self._thread.start()

    def put(self, pb):
        # bounded put that stays responsive to worker death: if this
        # replica's thread died with the queue full, a plain put() would
        # block the dispatcher forever and bury the error
        while True:
            if self.error is not None:
                raise RuntimeError(
                    f"replica {self.replica.idx} worker failed") \
                    from self.error
            try:
                self.handoff.put(pb, timeout=0.05)
                return
            except queue.Full:
                continue

    def finish(self) -> List[Completion]:
        try:
            self.put(None)
        except RuntimeError:
            pass                        # worker already dead; join + raise
        self._thread.join()
        if self.error is not None:
            raise RuntimeError(
                f"replica {self.replica.idx} worker failed") from self.error
        return self.completions

    def _loop(self):
        try:
            while True:
                pb = self.handoff.get()
                if pb is None:
                    return
                dev = self.devices[self._n % len(self.devices)]
                self._n += 1
                rids = [r.rid for r in pb.requests]
                t0 = self.clock()
                if self.delay is not None:
                    # injected straggler latency counts as device-busy time:
                    # a slow replica, not a gap in the trace
                    self.delay.apply(self.replica.idx)
                comps = self.replica.server.execute_prepared(pb, device=dev)
                t1 = self.clock()
                if self.metrics is not None:
                    self.metrics.on_device(rids, t0, t1,
                                           replica=self.replica.idx)
                    self.metrics.on_complete([c.rid for c in comps], t1)
                if self.tracer is not None:
                    # the exact t0/t1 handed to metrics, so TraceReport
                    # device percentiles reconcile with RunReport's
                    self.tracer.span("device_execute", t0, t1,
                                     replica=self.replica.idx, rids=rids)
                    done_rids = {c.rid for c in comps}
                    for c in comps:
                        self.tracer.mark("complete", t1, rid=c.rid,
                                         replica=self.replica.idx)
                    for rid in rids:
                        if rid not in done_rids:    # MCT filter drop
                            self.tracer.mark("drop", t1, rid=rid,
                                             replica=self.replica.idx,
                                             reason="filtered")
                self.completions.extend(comps)
                if self.on_batch_done is not None:
                    self.on_batch_done(self.replica.idx,
                                       batch_work(pb.requests), t1 - t0)
                if self.on_complete is not None:
                    for c in comps:
                        self.on_complete(c)
                if self.on_drop is not None:
                    done = {c.rid for c in comps}
                    for rid in rids:
                        if rid not in done:    # MCT filter drop
                            self.on_drop(rid)
        except BaseException as e:          # surfaced by put()/finish()
            self.error = e


class GroupRun:
    """One serving run over an :class:`EngineGroup`: per-replica pipelines
    plus the routing state. Create via :meth:`EngineGroup.open`; one-shot
    (dispatch until done, then :meth:`finish`)."""

    def __init__(self, group: "EngineGroup", *, pipeline_depth: int = 2,
                 metrics=None, clock=time.perf_counter,
                 on_complete=None, on_drop=None, tracer=None, cache=None):
        self.group = group
        self.metrics = metrics
        self.tracer = tracer
        self.cache = cache              # ResultCache: hit_aware affinity
                                        # hints (None = fall back to
                                        # least_loaded decisions)
        self._clock = clock
        self._workers = [
            _ReplicaWorker(rep, pipeline_depth, metrics,
                           on_complete=on_complete, on_drop=on_drop,
                           clock=clock, delay=group.delay,
                           on_batch_done=self._on_batch_done,
                           tracer=tracer)
            for rep in group.replicas]
        self._lock = threading.Lock()
        self._outstanding = [0] * len(self._workers)
        # per-replica EWMA of device seconds per work unit, fed by the
        # same t0/t1 the worker hands to metrics/trace — the straggler
        # signal hit_aware's affinity preference is guarded by. Shared
        # with (and persisted on) the group, so back-to-back runs keep
        # what they learned about slow replicas
        self._ewma: List[Optional[float]] = group._ewma
        self._rr = 0
        self._started = False
        # capacity control: replicas [0, _active) receive new dispatches;
        # parked replicas keep draining what they already hold
        self._active = len(self._workers)

    # -- hooks (closed-loop generators chain onto these) ---------------------
    @property
    def on_complete(self):
        return self._workers[0].on_complete

    @on_complete.setter
    def on_complete(self, cb):
        for w in self._workers:
            w.on_complete = cb

    @property
    def on_drop(self):
        return self._workers[0].on_drop

    @on_drop.setter
    def on_drop(self, cb):
        for w in self._workers:
            w.on_drop = cb

    @property
    def error(self) -> Optional[BaseException]:
        for w in self._workers:
            if w.error is not None:
                return w.error
        return None

    def outstanding(self) -> List[int]:
        """Per-replica outstanding work units (routing's view)."""
        with self._lock:
            return list(self._outstanding)

    @property
    def n_active(self) -> int:
        """Replicas currently receiving new dispatches."""
        with self._lock:
            return self._active

    def set_active(self, n: int) -> int:
        """Activate/park replicas: new batches route only to replicas
        ``[0, n)``. Parked replicas drain their pipelines but attract no
        new traffic (so they can be powered down / reassigned — the cost
        report charges only for active ones). Clamped to [1, n_replicas];
        returns the applied value."""
        with self._lock:
            self._active = max(1, min(len(self._workers), int(n)))
            return self._active

    def start(self) -> "GroupRun":
        if not self._started:
            self._started = True
            for w in self._workers:
                w.start()
        return self

    # -- routing -------------------------------------------------------------
    def replica_ewma(self) -> List[Optional[float]]:
        """Per-replica EWMA of device seconds per work unit (None until a
        replica has executed a batch) — the straggler signal."""
        with self._lock:
            return list(self._ewma)

    def _is_straggler_locked(self, idx: int, n: int) -> bool:
        """Replica ``idx`` is a straggler when its per-work-unit latency
        EWMA exceeds ``straggler_factor`` times the mean of the *other*
        active replicas (excluding itself, so one slow replica cannot drag
        the fleet mean up to its own level and hide)."""
        mine = self._ewma[idx]
        if mine is None:
            return False
        others = [e for j, e in enumerate(self._ewma[:n])
                  if j != idx and e is not None]
        if not others:
            return False
        return mine > self.group.straggler_factor * (sum(others)
                                                     / len(others))

    def _least_loaded_locked(self, loads: List[int],
                             exclude: Optional[int] = None) -> tuple:
        """(idx, reason) of the least-loaded candidate, round-robin among
        ties; ``exclude`` removes one replica from candidacy (the owner a
        spill is escaping from)."""
        cands_all = [i for i in range(len(loads)) if i != exclude]
        lo = min(loads[i] for i in cands_all)
        cands = [i for i in cands_all if loads[i] == lo]
        if len(cands) == 1:
            return cands[0], "least_loaded"
        i = cands[self._rr % len(cands)]
        self._rr += 1
        return i, "tie_break"

    def _route(self, pb) -> tuple:
        """Pick (replica_idx, reason, affinity_owner) for a prepared batch
        (active replicas only). ``affinity_owner`` is the cache-derived
        owner the decision was judged against (None when no hint applied:
        non-hit_aware policies, cache off, or no owned content)."""
        with self._lock:
            n = self._active
        if n == 1:
            return 0, "single", None
        if self.group.routing == RoutingPolicy.STICKY:
            return min(r.rid for r in pb.requests) % n, "sticky", None
        if self.group.routing == RoutingPolicy.HIT_AWARE \
                and self.cache is not None:
            from repro.serve.cache import request_key
            keys = [request_key(r) for r in pb.requests]
            votes = Counter(o for o in (self.cache.owner_hint(k)
                                        for k in keys)
                            if o is not None and 0 <= o < n)
            if votes:
                # majority owner of the batch's content, lowest index on
                # ties (deterministic)
                pref = max(sorted(votes), key=lambda i: votes[i])
                with self._lock:
                    loads = self._outstanding[:n]
                    lo = min(loads)
                    straggler = self._is_straggler_locked(pref, n)
                    spill = straggler or (loads[pref] - lo
                                          > self.group.spill_threshold)
                    if spill:
                        idx, _ = self._least_loaded_locked(loads,
                                                           exclude=pref)
                    else:
                        idx = pref
                if spill:
                    # re-home the content: follow-up recomputes of these
                    # keys chase the work to its new replica instead of
                    # re-testing (and re-failing) the old owner each time
                    for k in keys:
                        self.cache.rehome(k, idx)
                    return idx, "affinity_spill", pref
                return pref, "affinity_hit", pref
        with self._lock:
            loads = self._outstanding[:n]
            idx, reason = self._least_loaded_locked(loads)
        return idx, reason, None

    def _on_batch_done(self, idx: int, work: int, elapsed: float):
        with self._lock:
            self._outstanding[idx] -= work
            if work > 0 and elapsed >= 0:
                per_unit = elapsed / work
                prev = self._ewma[idx]
                a = self.group.ewma_alpha
                self._ewma[idx] = per_unit if prev is None \
                    else a * per_unit + (1 - a) * prev

    def dispatch(self, pb) -> int:
        """Route one prepared batch to a replica pipeline; blocks when that
        replica's handoff is full (that stall is the backpressure signal
        the admission queue sees). Returns the chosen replica index."""
        self.start()
        idx, reason, owner = self._route(pb)
        work = batch_work(pb.requests)
        with self._lock:
            self._outstanding[idx] += work
            depth_work = self._outstanding[idx]
        if self.metrics is not None:
            self.metrics.on_route(idx, reason)
        if self.tracer is not None:
            tags = {"reason": reason,
                    "rids": [r.rid for r in pb.requests]}
            if owner is not None:
                tags["owner"] = owner
            self.tracer.mark("dispatch", self._clock(), replica=idx,
                             **tags)
        self._workers[idx].put(pb)
        if self.metrics is not None:
            self.metrics.note_replica_depth(
                idx, self._workers[idx].handoff.qsize(), depth_work)
        return idx

    def finish(self) -> List[Completion]:
        """Drain every replica pipeline; raises if any replica worker
        failed. Completions are concatenated in replica order (callers
        match by rid — cross-replica completion order is not meaningful)."""
        self.start()
        out: List[Completion] = []
        first_err: Optional[BaseException] = None
        for w in self._workers:
            try:
                out.extend(w.finish())
            except RuntimeError as e:
                if first_err is None:
                    first_err = e
        if first_err is not None:
            raise first_err
        return out


class EngineGroup:
    """A replica set plus its routing policy — the sharded-serving
    counterpart of a single ``LMServer``. Reusable: each :meth:`open` (or
    :meth:`run_groups`) creates a fresh :class:`GroupRun` with its own
    per-replica pipelines."""

    def __init__(self, replicas: Sequence[Replica], *,
                 routing=RoutingPolicy.LEAST_LOADED, delay=None,
                 spill_threshold: int = 96, straggler_factor: float = 2.0,
                 ewma_alpha: float = 0.25):
        if not replicas:
            raise ValueError("EngineGroup needs at least one replica")
        self.routing = coerce_enum(RoutingPolicy, routing, field="routing")
        if spill_threshold < 0:
            raise ValueError(
                f"spill_threshold must be >= 0, got {spill_threshold}")
        if straggler_factor < 1.0:
            raise ValueError(
                f"straggler_factor must be >= 1.0, got {straggler_factor}")
        if not (0.0 < ewma_alpha <= 1.0):
            raise ValueError(
                f"ewma_alpha must be in (0, 1], got {ewma_alpha}")
        self.replicas = list(replicas)
        self.delay = delay              # optional DelayInjector (tests/sims)
        # hit_aware guard knobs (inert under other policies)
        self.spill_threshold = int(spill_threshold)
        self.straggler_factor = float(straggler_factor)
        self.ewma_alpha = float(ewma_alpha)
        # per-replica EWMA of device seconds per work unit — the straggler
        # signal. Lives on the *group* (like the cache's affinity map), so
        # a straggler identified in one run still repels traffic in the
        # next: runs are often shorter than the time a slow replica needs
        # to finish its first batch
        self._ewma: List[Optional[float]] = [None] * len(self.replicas)

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_server(cls, server, *, devices=None, replicas=None,
                    routing=RoutingPolicy.LEAST_LOADED, delay=None,
                    **knobs) -> "EngineGroup":
        """Replicas sharing one engine: one per device when ``devices`` is
        given (each pinned), else ``replicas`` colocated copies (host-device
        simulation / single-accelerator default)."""
        if devices:
            reps = [Replica(i, server, devices=[d])
                    for i, d in enumerate(devices)]
        else:
            reps = [Replica(i, server) for i in range(max(1, replicas or 1))]
        return cls(reps, routing=routing, delay=delay, **knobs)

    @classmethod
    def from_servers(cls, servers: Sequence, *,
                     routing=RoutingPolicy.LEAST_LOADED, delay=None,
                     **knobs) -> "EngineGroup":
        """One replica per (distinct) engine — used with simulated engines
        and with independently-built per-device servers."""
        return cls([Replica(i, s) for i, s in enumerate(servers)],
                   routing=routing, delay=delay, **knobs)

    @classmethod
    def from_mesh(cls, server, mesh, *, axis: str = "data",
                  routing=RoutingPolicy.LEAST_LOADED, delay=None,
                  **knobs) -> "EngineGroup":
        """One replica per slice of ``mesh`` along ``axis`` (see
        :func:`repro.sharding.specs.replica_device_groups`); the devices of
        each slice round-robin within the replica."""
        from repro.sharding.specs import replica_device_groups
        groups = replica_device_groups(mesh, axis=axis)
        return cls([Replica(i, server, devices=g)
                    for i, g in enumerate(groups)],
                   routing=routing, delay=delay, **knobs)

    # -- host-side prepare (replica-agnostic) --------------------------------
    def prepare_batch(self, requests):
        """Host-encode a batch. Prepare is replica-independent (all
        replicas serve the same model), so replica 0's engine does it."""
        return self.replicas[0].server.prepare_batch(requests)

    def open(self, *, pipeline_depth: int = 2, metrics=None,
             clock=time.perf_counter, on_complete=None,
             on_drop=None, tracer=None, cache=None) -> GroupRun:
        return GroupRun(self, pipeline_depth=pipeline_depth, metrics=metrics,
                        clock=clock, on_complete=on_complete,
                        on_drop=on_drop, tracer=tracer, cache=cache)

    def run_groups(self, groups, *, pipeline_depth: int = 2,
                   metrics=None, tracer=None, cache=None) -> List[Completion]:
        """Execute pre-formed batch groups through per-replica pipelines.

        Batch composition is fixed by the caller and every replica computes
        the same function, so completions are bit-identical to running the
        groups synchronously on one replica — only the placement and the
        host/device overlap differ. This is the single implementation
        behind ``Server.serve(mode="pipelined")``.
        """
        run = self.open(pipeline_depth=pipeline_depth, metrics=metrics,
                        tracer=tracer, cache=cache).start()
        try:
            for rs in groups:
                rs = list(rs)
                if not rs:
                    continue
                t0 = time.perf_counter()
                pb = self.prepare_batch(rs)     # overlaps device execution
                t1 = time.perf_counter()
                if metrics is not None:
                    metrics.on_encode([r.rid for r in rs], t0, t1)
                if tracer is not None:
                    tracer.span("encode", t0, t1,
                                rids=[r.rid for r in rs])
                run.dispatch(pb)
        except BaseException:
            # prepare/dispatch failed mid-run: reap every replica worker
            # thread before propagating, so a failed serve() never leaks
            # the pipeline (finish() errors must not mask the original)
            try:
                run.finish()
            except Exception:
                pass
            raise
        return run.finish()

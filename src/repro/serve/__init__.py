"""Serving subsystem: batched LM server + asynchronous submission pipeline.

- ``engine``    — LMServer (prepare/execute split), Request/Completion
- ``scheduler`` — AsyncScheduler (bounded admission, backpressure,
                  double-buffered host/device overlap), run_pipelined
- ``loadgen``   — open-loop (Poisson) / closed-loop (fixed concurrency)
                  seeded load generators
- ``metrics``   — per-request latency breakdown, device-idle-fraction
"""
from repro.serve.engine import (Completion, LMServer, PreparedBatch,
                                Request)
from repro.serve.loadgen import (ClosedLoopGen, OpenLoopGen,
                                 SyntheticWorkload, poisson_arrivals,
                                 uniform_arrivals)
from repro.serve.metrics import (LatencyStats, MetricsCollector,
                                 RequestTrace, RunReport)
from repro.serve.scheduler import (AsyncScheduler, SchedulerConfig,
                                   run_pipelined)

__all__ = [
    "Completion", "LMServer", "PreparedBatch", "Request",
    "ClosedLoopGen", "OpenLoopGen", "SyntheticWorkload",
    "poisson_arrivals", "uniform_arrivals",
    "LatencyStats", "MetricsCollector", "RequestTrace", "RunReport",
    "AsyncScheduler", "SchedulerConfig", "run_pipelined",
]

"""Serving subsystem: sharded multi-replica serving behind one front end.

Preferred API — one config, one call:

    from repro.serve import ServeConfig, build
    srv = build(ServeConfig(model="llama3.2-3b", replicas=2))
    srv.serve(requests, mode="pipelined")     # deterministic replay
    sched = srv.session()                     # live bounded-admission serving

Modules:

- ``server``    — ServeConfig + build() -> Server facade
- ``engine``    — LMServer (prepare/execute split), Request/Completion,
                  form_batch_groups (logical-time batch formation)
- ``group``     — EngineGroup/Replica: one engine replica per device or
                  mesh slice, least-outstanding-work / sticky routing,
                  per-replica host-encode/device-execute pipelines
- ``scheduler`` — AsyncScheduler (bounded admission, BackpressurePolicy
                  REJECT/SHED_OLDEST/BLOCK), deprecated run_pipelined shim
- ``cache``     — content-addressed ResultCache (TTL + byte-bounded LRU,
                  optional negative caching of MCT-filtered verdicts) and
                  single-flight Coalescer with shed-leader promotion;
                  enable via ``ServeConfig(cache=CacheConfig(...))``
                  (default off)
- ``capacity``  — BottleneckMonitor (host/device/admission-bound
                  diagnosis with hysteresis), CapacityController
                  (adaptive batch-target / replica-set / AIMD admission
                  control), CostReport ($/1k-queries through the paper's
                  deployment prices); enable via
                  ``ServeConfig(capacity=CapacityConfig(...))``
                  (default off)
- ``sim``       — SimServer: wall-clock host/device cost simulation for
                  replica-scaling studies without real accelerators
                  (``SIM_PROFILES`` name the paper's box shapes)
- ``loadgen``   — open-loop (Poisson, optionally phase-shifting) /
                  closed-loop (fixed concurrency) seeded load generators,
                  optional Zipfian key-reuse
- ``metrics``   — per-request latency breakdown, device-idle-fraction,
                  per-replica queue depth / idle / routing / cache
                  counters, cumulative SignalSnapshot windows for the
                  capacity subsystem
"""
from repro.serve.cache import (CacheConfig, CachedResult, Coalescer,
                               NegativeResult, ResultCache, request_key)
from repro.serve.capacity import (Bottleneck, BottleneckMonitor,
                                  CapacityConfig, CapacityController,
                                  CapacitySignals, ControllerAction,
                                  CostReport)
from repro.serve.engine import (Completion, LMServer, PreparedBatch,
                                Request, form_batch_groups)
from repro.serve.group import (EngineGroup, GroupRun, Replica,
                               RoutingPolicy, batch_work)
from repro.serve.loadgen import (ClosedLoopGen, OpenLoopGen,
                                 PhasedOpenLoopGen, SyntheticWorkload,
                                 poisson_arrivals, uniform_arrivals,
                                 zipf_probs)
from repro.serve.metrics import (LatencyStats, MetricsCollector,
                                 ReplicaStats, RequestTrace, RunReport,
                                 SignalSnapshot)
from repro.serve.scheduler import (AsyncScheduler, BackpressurePolicy,
                                   SchedulerConfig, run_pipelined)
from repro.serve.server import ServeConfig, Server, build
from repro.serve.sim import SIM_PROFILES, SimProfile, SimServer, sim_requests

__all__ = [
    "CacheConfig", "CachedResult", "Coalescer", "NegativeResult",
    "ResultCache", "request_key",
    "Bottleneck", "BottleneckMonitor", "CapacityConfig",
    "CapacityController", "CapacitySignals", "ControllerAction",
    "CostReport",
    "Completion", "LMServer", "PreparedBatch", "Request",
    "form_batch_groups",
    "EngineGroup", "GroupRun", "Replica", "RoutingPolicy", "batch_work",
    "ClosedLoopGen", "OpenLoopGen", "PhasedOpenLoopGen",
    "SyntheticWorkload",
    "poisson_arrivals", "uniform_arrivals", "zipf_probs",
    "LatencyStats", "MetricsCollector", "ReplicaStats", "RequestTrace",
    "RunReport", "SignalSnapshot",
    "AsyncScheduler", "BackpressurePolicy", "SchedulerConfig",
    "run_pipelined",
    "ServeConfig", "Server", "build",
    "SIM_PROFILES", "SimProfile", "SimServer", "sim_requests",
]

"""Serving subsystem: sharded multi-replica serving behind one front end.

Preferred API — one config, one call:

    from repro.serve import ServeConfig, build
    srv = build(ServeConfig(model="llama3.2-3b", replicas=2))
    srv.serve(requests, mode="pipelined")     # deterministic replay
    sched = srv.session()                     # live bounded-admission serving

Modules:

- ``server``    — ServeConfig + build() -> Server facade
- ``engine``    — LMServer (prepare/execute split), Request/Completion,
                  form_batch_groups (logical-time batch formation)
- ``group``     — EngineGroup/Replica: one engine replica per device or
                  mesh slice, least-outstanding-work / sticky routing,
                  per-replica host-encode/device-execute pipelines
- ``scheduler`` — AsyncScheduler (bounded admission, BackpressurePolicy
                  REJECT/SHED_OLDEST/BLOCK), deprecated run_pipelined shim
- ``cache``     — content-addressed ResultCache (TTL + byte-bounded LRU)
                  and single-flight Coalescer; enable via
                  ``ServeConfig(cache=CacheConfig(...))`` (default off)
- ``sim``       — SimServer: wall-clock host/device cost simulation for
                  replica-scaling studies without real accelerators
- ``loadgen``   — open-loop (Poisson) / closed-loop (fixed concurrency)
                  seeded load generators, optional Zipfian key-reuse
- ``metrics``   — per-request latency breakdown, device-idle-fraction,
                  per-replica queue depth / idle / routing / cache counters
"""
from repro.serve.cache import (CacheConfig, CachedResult, Coalescer,
                               ResultCache, request_key)
from repro.serve.engine import (Completion, LMServer, PreparedBatch,
                                Request, form_batch_groups)
from repro.serve.group import (EngineGroup, GroupRun, Replica,
                               RoutingPolicy, batch_work)
from repro.serve.loadgen import (ClosedLoopGen, OpenLoopGen,
                                 SyntheticWorkload, poisson_arrivals,
                                 uniform_arrivals, zipf_probs)
from repro.serve.metrics import (LatencyStats, MetricsCollector,
                                 ReplicaStats, RequestTrace, RunReport)
from repro.serve.scheduler import (AsyncScheduler, BackpressurePolicy,
                                   SchedulerConfig, run_pipelined)
from repro.serve.server import ServeConfig, Server, build
from repro.serve.sim import SimServer, sim_requests

__all__ = [
    "CacheConfig", "CachedResult", "Coalescer", "ResultCache",
    "request_key",
    "Completion", "LMServer", "PreparedBatch", "Request",
    "form_batch_groups",
    "EngineGroup", "GroupRun", "Replica", "RoutingPolicy", "batch_work",
    "ClosedLoopGen", "OpenLoopGen", "SyntheticWorkload",
    "poisson_arrivals", "uniform_arrivals", "zipf_probs",
    "LatencyStats", "MetricsCollector", "ReplicaStats", "RequestTrace",
    "RunReport",
    "AsyncScheduler", "BackpressurePolicy", "SchedulerConfig",
    "run_pipelined",
    "ServeConfig", "Server", "build",
    "SimServer", "sim_requests",
]

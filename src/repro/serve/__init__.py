"""Serving subsystem: sharded multi-replica serving behind one front end.

Preferred API — one config, one call:

    from repro.serve import ServeConfig, build, serve
    srv = build(ServeConfig(model="llama3.2-3b", replicas=2))
    srv.serve(requests, mode="pipelined")     # deterministic replay
    sched = srv.session()                     # live bounded-admission serving
    outs, report = serve(requests, replicas=2)  # one-call convenience

Modules:

- ``server``    — ServeConfig + build() -> Server facade, serve() one-call
                  convenience
- ``engine``    — LMServer (prepare/execute split), Request/Completion,
                  form_batch_groups (logical-time batch formation)
- ``group``     — EngineGroup/Replica: one engine replica per device or
                  mesh slice, least-outstanding-work / sticky /
                  hit-aware (cache-ownership affinity with straggler
                  spill) routing, per-replica host-encode/device-execute
                  pipelines
- ``scheduler`` — AsyncScheduler (bounded admission, BackpressurePolicy
                  REJECT/SHED_OLDEST/BLOCK)
- ``trace``     — per-request lifecycle tracing: Tracer (bounded ring of
                  Span records across submit → queue_wait → encode →
                  dispatch → device_execute → complete), TraceReport
                  (per-stage percentiles + per-replica straggler
                  attribution), Chrome ``trace_event`` / JSONL exporters;
                  enable via ``ServeConfig(trace=True)`` (default off —
                  the disabled stack is bit-identical)
- ``config``    — shared coerce() rule (None/False -> off, True -> cls(),
                  dict -> cls(**d)) used by every sub-config field
- ``cache``     — content-addressed ResultCache (TTL + byte-bounded LRU,
                  optional negative caching of MCT-filtered verdicts) and
                  single-flight Coalescer with shed-leader promotion;
                  enable via ``ServeConfig(cache=CacheConfig(...))``
                  (default off)
- ``capacity``  — BottleneckMonitor (host/device/admission-bound
                  diagnosis with hysteresis), CapacityController
                  (adaptive batch-target / replica-set / AIMD admission
                  control), CostReport ($/1k-queries through the paper's
                  deployment prices); enable via
                  ``ServeConfig(capacity=CapacityConfig(...))``
                  (default off)
- ``sim``       — SimServer: wall-clock host/device cost simulation for
                  replica-scaling studies without real accelerators
                  (``SIM_PROFILES`` name the paper's box shapes)
- ``loadgen``   — open-loop (Poisson, optionally phase-shifting) /
                  closed-loop (fixed concurrency) seeded load generators,
                  optional Zipfian key-reuse
- ``metrics``   — per-request latency breakdown, device-idle-fraction,
                  per-replica queue depth / idle / routing / cache
                  counters, cumulative SignalSnapshot windows for the
                  capacity subsystem
"""
from repro.serve.cache import (CacheConfig, CachedResult, Coalescer,
                               NegativeResult, ResultCache, request_key)
from repro.serve.capacity import (Bottleneck, BottleneckMonitor,
                                  CapacityConfig, CapacityController,
                                  CapacitySignals, ControllerAction,
                                  CostReport)
from repro.serve.engine import (Completion, LMServer, PreparedBatch,
                                Request, form_batch_groups)
from repro.serve.group import (EngineGroup, GroupRun, Replica,
                               RoutingPolicy, batch_work)
from repro.serve.loadgen import (ClosedLoopGen, OpenLoopGen,
                                 PhasedOpenLoopGen, SyntheticWorkload,
                                 poisson_arrivals, uniform_arrivals,
                                 zipf_probs)
from repro.serve.metrics import (LatencyStats, MetricsCollector,
                                 ReplicaStats, RequestTrace, RunReport,
                                 SignalSnapshot)
from repro.serve.config import Coercible, coerce
from repro.serve.scheduler import (AsyncScheduler, BackpressurePolicy,
                                   SchedulerConfig)
from repro.serve.server import ServeConfig, Server, build, serve
from repro.serve.sim import SIM_PROFILES, SimProfile, SimServer, sim_requests
from repro.serve.trace import (ReplicaTraceStats, Span, TraceConfig,
                               TraceReport, Tracer, render_timeline)

__all__ = [
    "CacheConfig", "CachedResult", "Coalescer", "NegativeResult",
    "ResultCache", "request_key",
    "Bottleneck", "BottleneckMonitor", "CapacityConfig",
    "CapacityController", "CapacitySignals", "ControllerAction",
    "CostReport",
    "Completion", "LMServer", "PreparedBatch", "Request",
    "form_batch_groups",
    "EngineGroup", "GroupRun", "Replica", "RoutingPolicy", "batch_work",
    "ClosedLoopGen", "OpenLoopGen", "PhasedOpenLoopGen",
    "SyntheticWorkload",
    "poisson_arrivals", "uniform_arrivals", "zipf_probs",
    "LatencyStats", "MetricsCollector", "ReplicaStats", "RequestTrace",
    "RunReport", "SignalSnapshot",
    "AsyncScheduler", "BackpressurePolicy", "SchedulerConfig",
    "ServeConfig", "Server", "build", "serve",
    "SIM_PROFILES", "SimProfile", "SimServer", "sim_requests",
    "Coercible", "coerce",
    "ReplicaTraceStats", "Span", "TraceConfig", "TraceReport", "Tracer",
    "render_timeline",
]

"""Serving engine: batched prefill + decode with the paper's batch-formation
policy driving request aggregation.

The paper's lesson (§5): the accelerator is only competitive when the
integration layer forms large enough batches — so the server's front end IS
the DeadlineAggregator (target batch + SLA deadline), and the MCT rule
engine plugs in as a request-filtering stage ahead of the LM (the paper's
Fig 14 co-location of MCT + Route Scoring on one accelerator).

The batched path is split into a host-side **prepare** stage (token-matrix
assembly + MCT query encoding, pure numpy) and a device-side **execute**
stage (rule matching + decode loop). ``repro.serve.group.EngineGroup`` and
``repro.serve.scheduler`` exploit the split to overlap host encode of batch
N+1 with device execution of batch N — the imbalance the paper's §5–6
identify as the deployment's make-or-break.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.aggregator import DeadlineAggregator
from repro.models.registry import build_model


@dataclass
class Request:
    rid: int
    tokens: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 16
    arrival: float = 0.0
    # MCT filtering stage inputs: connection queries + actual connect times
    mct_queries: List[Dict[str, int]] = field(default_factory=list)
    connect_minutes: List[int] = field(default_factory=list)


@dataclass
class Completion:
    rid: int
    tokens: np.ndarray            # generated ids
    prefill_ms: float
    decode_ms: float
    batch_size: int
    truncated: bool = False       # hit the max_seq context limit before
                                  # max_new_tokens were produced


@dataclass
class PreparedBatch:
    """Host-side half of a batch: everything the device stage needs,
    assembled without touching the accelerator."""
    requests: List[Request]
    toks: np.ndarray                      # (B, max_plen) int32
    plens: List[int]
    max_new: int
    mct_encoded: Optional[np.ndarray]     # (Q, C) int32 or None
    mct_owner: List[int] = field(default_factory=list)  # query -> request idx


def form_batch_groups(requests: Sequence[Request], *, target_batch: int = 8,
                      deadline: float = 0.05) -> List[List[Request]]:
    """Replay an arrival-ordered request stream through the paper's
    deadline policy; logical time, so batch composition is deterministic
    for a given stream. Engine-independent: any server implementing the
    prepare/execute protocol (LMServer, SimServer) can run the groups."""
    agg = DeadlineAggregator(target_batch=target_batch, deadline=deadline)
    batches = []
    for r in sorted(requests, key=lambda x: x.arrival):
        batches.extend(agg.offer(r.rid, [r], now=r.arrival))
    batches.extend(agg.flush())
    return [[q for q in b.queries] for b in batches]


class LMServer:
    """Batched prefill + decode-loop serving for any registry architecture."""

    def __init__(self, cfg: ModelConfig, params=None, *, ctx=None,
                 max_seq: int = 256, seed: int = 0,
                 rule_filter=None, pad_batches: bool = True):
        self.cfg = cfg
        self.model = build_model(cfg, ctx)
        self.params = params if params is not None \
            else self.model.init(jax.random.PRNGKey(seed))
        self.max_seq = max_seq
        self.rule_filter = rule_filter      # optional ErbiumEngine stage
        # batch-size bucketing: pad each batch to the next power of two so
        # the jitted decode step compiles O(log B) variants instead of one
        # per distinct batch size — without it, a deadline-formed stream of
        # ragged batches is a compile storm. Rows are independent (masked
        # attention), so padding never changes per-request results.
        self.pad_batches = pad_batches
        self._decode = jax.jit(
            lambda p, c, t, pos: self.model.decode_step(p, c, t, pos),
            donate_argnums=(1,))
        self._dev_params: Dict[object, object] = {}

    # -- host-side prepare stage ----------------------------------------------
    def prepare_batch(self, requests: Sequence[Request]) -> PreparedBatch:
        """Assemble the token matrix and encode MCT queries — pure host
        (numpy) work, safe to run while the device executes another batch."""
        rs = list(requests)
        plens = [len(r.tokens) for r in rs]
        max_new = max((r.max_new_tokens for r in rs), default=0)
        toks = np.zeros((len(rs), max(plens, default=0)), np.int32)
        for i, r in enumerate(rs):
            toks[i, :plens[i]] = r.tokens
        mct_encoded, owner = None, []
        if self.rule_filter is not None:
            flat = []
            for i, r in enumerate(rs):
                for q in r.mct_queries:
                    flat.append(q)
                    owner.append(i)
            if flat:
                mct_encoded = self.rule_filter.encode_queries_host(flat)
        return PreparedBatch(requests=rs, toks=toks, plens=plens,
                             max_new=max_new, mct_encoded=mct_encoded,
                             mct_owner=owner)

    # -- device-side execute stage --------------------------------------------
    def execute_prepared(self, pb: PreparedBatch, *,
                         device=None) -> List[Completion]:
        """Run the device half: MCT rule matching (drops infeasible
        requests), then the batched prefill + decode loop. ``device`` pins
        execution to a specific jax device (the scheduler round-robins
        batches across devices when given several)."""
        rs = pb.requests
        if not rs:
            return []
        toks, plens, max_new = pb.toks, pb.plens, pb.max_new
        if self.rule_filter is not None and pb.mct_encoded is not None:
            keep = self._mct_feasible(rs, pb.mct_encoded, pb.mct_owner)
            if not all(keep):
                # slice the already-prepared rows — no host re-encode on
                # the device thread's critical path
                idx = [i for i, ok in enumerate(keep) if ok]
                if not idx:
                    return []
                rs = [rs[i] for i in idx]
                toks = toks[idx]
                plens = [plens[i] for i in idx]
                max_new = max(r.max_new_tokens for r in rs)
        return self._run_decode(rs, toks, plens, max_new, device=device)

    def generate_batch(self, requests: Sequence[Request]) -> List[Completion]:
        """prepare + execute in one synchronous call (the baseline path).
        Applies the MCT filter stage when the server has one."""
        if not requests:
            return []
        return self.execute_prepared(self.prepare_batch(requests))

    def warmup(self, batch_sizes: Sequence[int] = (1, 8), *,
               prompt_len: int = 4, max_new_tokens: int = 2) -> None:
        """Pre-compile the decode step for the bucketed batch sizes so the
        first live batches don't pay JIT latency (benchmarks call this
        before timing)."""
        for b in batch_sizes:
            reqs = [Request(rid=-1 - i,
                            tokens=np.ones(prompt_len, np.int32),
                            max_new_tokens=max_new_tokens, mct_queries=[],
                            connect_minutes=[])
                    for i in range(b)]
            self._run_decode(reqs, np.ones((b, prompt_len), np.int32),
                             [prompt_len] * b, max_new_tokens)

    def _params_on(self, device):
        if device is None:
            return self.params
        if device not in self._dev_params:
            self._dev_params[device] = jax.device_put(self.params, device)
        return self._dev_params[device]

    def _run_decode(self, rs: List[Request], toks: np.ndarray,
                    plens: List[int], max_new: int,
                    device=None) -> List[Completion]:
        t0 = time.perf_counter()
        B = len(rs)
        total = self.max_seq
        max_p = max(plens)
        if max_p >= total:
            # hard error, not an assert: the scheduler's worker-death
            # propagation relies on this raising even under python -O,
            # and proceeding would silently corrupt the KV cache
            raise ValueError(
                f"max_seq={total} too small for the prompt alone "
                f"(longest prompt: {max_p})")

        Bp = B
        if self.pad_batches and B > 1:
            Bp = 1 << (B - 1).bit_length()      # next power of two
        if Bp != B:
            toks = np.concatenate(
                [toks, np.zeros((Bp - B, toks.shape[1]), np.int32)])

        params = self._params_on(device)
        cache = self.model.init_cache(Bp, total)
        if device is not None:
            cache = jax.device_put(cache, device)
        # prefill via the decode path, token by token up to each prompt len
        # (keeps one compiled step; a fused prefill kernel is the fast path
        # for attention archs and is exercised in tests via model.prefill)
        generated = [[] for _ in range(B)]
        last_logits = None
        for pos in range(max_p):
            step_tok = jnp.asarray(toks[:, pos:pos + 1])
            last_logits, cache = self._decode(params, cache, step_tok,
                                              jnp.int32(pos))
        t1 = time.perf_counter()

        cur = np.asarray(jnp.argmax(last_logits[:, -1], axis=-1),
                         np.int32)
        for s in range(max_new):
            for i in range(B):
                if s < rs[i].max_new_tokens:
                    generated[i].append(int(cur[i]))
            pos = max_p + s
            if pos >= total - 1 or s == max_new - 1:
                break
            logits, cache = self._decode(params, cache,
                                         jnp.asarray(cur[:, None]),
                                         jnp.int32(pos))
            cur = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        jax.block_until_ready(cur)
        t2 = time.perf_counter()

        return [Completion(rid=r.rid, tokens=np.asarray(g, np.int32),
                           prefill_ms=(t1 - t0) * 1e3,
                           decode_ms=(t2 - t1) * 1e3, batch_size=B,
                           truncated=len(g) < r.max_new_tokens)
                for r, g in zip(rs, generated)]

    # -- continuous batching front end ----------------------------------------
    def form_batches(self, requests: Sequence[Request], *,
                     target_batch: int = 8, deadline: float = 0.05
                     ) -> List[List[Request]]:
        """Replay an arrival-ordered request stream through the paper's
        deadline policy (see module-level :func:`form_batch_groups`)."""
        return form_batch_groups(requests, target_batch=target_batch,
                                 deadline=deadline)

    def _mct_feasible(self, rs: List[Request], encoded: np.ndarray,
                      owner: List[int]) -> List[bool]:
        """MCT filtering stage: all connection queries of the batch were
        encoded host-side into ONE kernel input (the paper's aggregation
        lesson); match on device, then drop requests with an infeasible
        connection (connect time < MCT)."""
        dec, _, _ = self.rule_filter.match(encoded)
        dec = np.asarray(dec)
        feasible = [True] * len(rs)
        pos = {i: 0 for i in range(len(rs))}
        for j, i in enumerate(owner):
            mct = int(dec[j])
            if mct < 0:
                mct = self.rule_filter.table.default_decision
            have = rs[i].connect_minutes[pos[i]] \
                if pos[i] < len(rs[i].connect_minutes) else 10 ** 6
            pos[i] += 1
            if have < mct:
                feasible[i] = False
        return feasible

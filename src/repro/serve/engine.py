"""Serving engine: batched prefill + decode with the paper's batch-formation
policy driving request aggregation.

The paper's lesson (§5): the accelerator is only competitive when the
integration layer forms large enough batches — so the server's front end IS
the DeadlineAggregator (target batch + SLA deadline), and the MCT rule
engine plugs in as a request-filtering stage ahead of the LM (the paper's
Fig 14 co-location of MCT + Route Scoring on one accelerator).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.aggregator import DeadlineAggregator
from repro.models.registry import Model, build_model


@dataclass
class Request:
    rid: int
    tokens: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 16
    arrival: float = 0.0
    # MCT filtering stage inputs: connection queries + actual connect times
    mct_queries: List[Dict[str, int]] = field(default_factory=list)
    connect_minutes: List[int] = field(default_factory=list)


@dataclass
class Completion:
    rid: int
    tokens: np.ndarray            # generated ids
    prefill_ms: float
    decode_ms: float
    batch_size: int


class LMServer:
    """Batched prefill + decode-loop serving for any registry architecture."""

    def __init__(self, cfg: ModelConfig, params=None, *, ctx=None,
                 max_seq: int = 256, seed: int = 0,
                 rule_filter=None):
        self.cfg = cfg
        self.model = build_model(cfg, ctx)
        self.params = params if params is not None \
            else self.model.init(jax.random.PRNGKey(seed))
        self.max_seq = max_seq
        self.rule_filter = rule_filter      # optional ErbiumEngine stage
        self._decode = jax.jit(
            lambda p, c, t, pos: self.model.decode_step(p, c, t, pos),
            donate_argnums=(1,))

    # -- core batched path ----------------------------------------------------
    def generate_batch(self, requests: Sequence[Request]) -> List[Completion]:
        if not requests:
            return []
        t0 = time.perf_counter()
        B = len(requests)
        plens = [len(r.tokens) for r in requests]
        max_new = max(r.max_new_tokens for r in requests)
        total = self.max_seq
        assert max(plens) + max_new <= total, "max_seq too small"

        cache = self.model.init_cache(B, total)
        # prefill via the decode path, token by token up to each prompt len
        # (keeps one compiled step; a fused prefill kernel is the fast path
        # for attention archs and is exercised in tests via model.prefill)
        toks = np.zeros((B, max(plens)), np.int32)
        for i, r in enumerate(requests):
            toks[i, :plens[i]] = r.tokens
        generated = [[] for _ in range(B)]
        last_logits = None
        for pos in range(max(plens)):
            step_tok = jnp.asarray(toks[:, pos:pos + 1])
            last_logits, cache = self._decode(self.params, cache, step_tok,
                                              jnp.int32(pos))
        t1 = time.perf_counter()

        cur = np.asarray(jnp.argmax(last_logits[:, -1], axis=-1),
                         np.int32)
        for s in range(max_new):
            for i in range(B):
                if s < requests[i].max_new_tokens:
                    generated[i].append(int(cur[i]))
            pos = max(plens) + s
            if pos >= total - 1 or s == max_new - 1:
                break
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(cur[:, None]),
                                         jnp.int32(pos))
            cur = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        t2 = time.perf_counter()

        return [Completion(rid=r.rid, tokens=np.asarray(g, np.int32),
                           prefill_ms=(t1 - t0) * 1e3,
                           decode_ms=(t2 - t1) * 1e3, batch_size=B)
                for r, g in zip(requests, generated)]

    # -- continuous batching front end ----------------------------------------
    def serve_stream(self, requests: Sequence[Request], *,
                     target_batch: int = 8, deadline: float = 0.05
                     ) -> List[Completion]:
        """Aggregate an arrival-ordered request stream with the paper's
        deadline policy, then run batches."""
        agg = DeadlineAggregator(target_batch=target_batch,
                                 deadline=deadline)
        by_rid = {r.rid: r for r in requests}
        batches = []
        for r in sorted(requests, key=lambda x: x.arrival):
            batches.extend(agg.offer(r.rid, [{"rid": r.rid}], now=r.arrival))
        batches.extend(agg.flush())
        out: List[Completion] = []
        for b in batches:
            rs = [by_rid[uid] for uid, _ in b.ts_index]
            if self.rule_filter is not None:
                rs = self._filter(rs)
            out.extend(self.generate_batch(rs))
        return out

    def _filter(self, rs: List[Request]) -> List[Request]:
        """MCT filtering stage: batch ALL connection queries of the batch
        into ONE rule-engine call (the paper's aggregation lesson), then drop
        requests with an infeasible connection (connect time < MCT)."""
        flat, owner = [], []
        for i, r in enumerate(rs):
            for q in r.mct_queries:
                flat.append(q)
                owner.append(i)
        if not flat:
            return list(rs)
        dec, _, _ = self.rule_filter.match_queries(flat)
        dec = np.asarray(dec)
        feasible = [True] * len(rs)
        pos = {i: 0 for i in range(len(rs))}
        for j, i in enumerate(owner):
            mct = int(dec[j])
            if mct < 0:
                mct = self.rule_filter.table.default_decision
            have = rs[i].connect_minutes[pos[i]] \
                if pos[i] < len(rs[i].connect_minutes) else 10 ** 6
            pos[i] += 1
            if have < mct:
                feasible[i] = False
        return [r for r, ok in zip(rs, feasible) if ok]

"""Open-loop and closed-loop load generation for the serving pipeline.

The paper's §6 regime — "the CPU cannot generate enough load to saturate
the accelerator" — needs two controllable axes to reproduce:

- **arrival process**: open loop (Poisson arrivals at a target QPS,
  independent of service rate — models front-end fan-in) vs closed loop
  (fixed concurrency, each completion releases the next submission —
  models a worker pool).
- **host-side work per request**: prompt length drives tokenisation cost,
  MCT query count drives encoder cost. Dialing these up makes the host the
  bottleneck and the device-idle-fraction climb, which is the imbalance
  curve the fig13 harness sweeps.

Everything is seeded: the same (seed, qps, n) always yields the same
arrival schedule and request contents.

A third axis matters once the serving layer caches results
(``repro.serve.cache``): **content repetition**. Travel-search traffic
re-asks the same origin/destination/date queries within seconds, so
``SyntheticWorkload(unique_keys=K, repeat_alpha=a)`` draws every request's
content from ``K`` fixed prototypes with Zipf(``a``) popularity —
``a = 0`` is uniform reuse, larger ``a`` concentrates traffic on the head
keys. Both the open- and closed-loop generators inherit the mode through
their workload. The default (``unique_keys = 0``) keeps the original
every-request-unique stream byte-identical.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.serve.engine import Request


def poisson_arrivals(n: int, qps: float, *, seed: int = 0,
                     start: float = 0.0) -> np.ndarray:
    """Cumulative arrival times of a Poisson process at rate ``qps``."""
    rng = np.random.default_rng(seed)
    return start + np.cumsum(rng.exponential(1.0 / qps, size=n))


def uniform_arrivals(n: int, qps: float, *, start: float = 0.0) -> np.ndarray:
    """Deterministic evenly-spaced arrivals (useful as a control)."""
    return start + (np.arange(n, dtype=np.float64) + 1.0) / qps


def zipf_probs(k: int, alpha: float) -> np.ndarray:
    """Zipf(``alpha``) popularity over ``k`` ranked keys (``alpha = 0`` is
    uniform): p(rank r) proportional to 1 / r**alpha."""
    if k <= 0:
        raise ValueError(f"need k >= 1 keys, got {k}")
    w = np.arange(1, k + 1, dtype=np.float64) ** -float(alpha)
    return w / w.sum()


@dataclass
class SyntheticWorkload:
    """Seeded request factory with dialable host-side work per request.

    With ``unique_keys > 0`` the stream draws request *content* (tokens +
    MCT queries) from that many fixed prototypes under Zipf
    (``repeat_alpha``) popularity — repeat-heavy traffic for result-cache
    studies. Two requests drawn from the same prototype are content-equal
    (same ``repro.serve.cache.request_key``) even though their rids and
    arrivals differ. The default ``unique_keys = 0`` keeps every request's
    content unique and byte-identical to the pre-cache generator.
    """
    vocab: int = 256
    prompt_len: int = 8
    max_new_tokens: int = 4
    n_mct_queries: int = 0        # >0 needs ``ruleset`` for query synthesis
    ruleset: object = None
    seed: int = 0
    # content repetition (off by default): number of distinct request
    # prototypes and the Zipf popularity skew across them
    unique_keys: int = 0
    repeat_alpha: float = 0.0

    def build(self, n: int, arrivals: Optional[np.ndarray] = None,
              rid_base: int = 0) -> List[Request]:
        rng = np.random.default_rng(self.seed)
        n_content = self.unique_keys if self.unique_keys > 0 else n
        mct_pool: List[dict] = []
        if self.n_mct_queries > 0:
            if self.ruleset is None:
                raise ValueError("n_mct_queries > 0 requires a ruleset")
            from repro.core.rules import generate_queries
            mct_pool = generate_queries(self.ruleset,
                                        n_content * self.n_mct_queries,
                                        seed=self.seed)
        protos: Optional[List[np.ndarray]] = None
        choice: Optional[np.ndarray] = None
        if self.unique_keys > 0:
            protos = [rng.integers(1, self.vocab,
                                   self.prompt_len).astype(np.int32)
                      for _ in range(self.unique_keys)]
            choice = rng.choice(self.unique_keys, size=n,
                                p=zipf_probs(self.unique_keys,
                                             self.repeat_alpha))
        out = []
        for i in range(n):
            j = int(choice[i]) if choice is not None else i
            toks = protos[j].copy() if protos is not None \
                else rng.integers(1, self.vocab,
                                  self.prompt_len).astype(np.int32)
            qs = mct_pool[j * self.n_mct_queries:(j + 1) * self.n_mct_queries]
            out.append(Request(
                rid=rid_base + i,
                tokens=toks,
                max_new_tokens=self.max_new_tokens,
                arrival=float(arrivals[i]) if arrivals is not None else 0.0,
                mct_queries=list(qs),
                # generous connect times: the MCT stage encodes/matches but
                # does not drop, so loadgen comparisons stay apples-to-apples
                connect_minutes=[10 ** 6] * len(qs)))
        return out


@dataclass
class OpenLoopGen:
    """Poisson arrivals at ``qps``, submitted regardless of completions."""
    workload: SyntheticWorkload
    qps: float
    n: int
    seed: int = 0

    def requests(self) -> List[Request]:
        """Arrival-stamped requests for deterministic logical-time replay
        (``LMServer.form_batches`` / ``Server.serve``)."""
        arr = poisson_arrivals(self.n, self.qps, seed=self.seed)
        return self.workload.build(self.n, arrivals=arr)

    def drive(self, scheduler, *, time_scale: float = 1.0) -> int:
        """Live submission: sleep out the schedule, fire-and-forget into
        the scheduler (never waits on completions — open loop). Returns
        how many submissions were accepted."""
        reqs = self.requests()
        t0 = time.perf_counter()
        accepted = 0
        for r in reqs:
            delay = r.arrival * time_scale - (time.perf_counter() - t0)
            if delay > 0:
                time.sleep(delay)
            accepted += bool(scheduler.submit(r))
        return accepted


@dataclass
class PhasedOpenLoopGen:
    """Open-loop load whose target QPS shifts through phases — the traffic
    shape that motivates *online* capacity control: a controller tuned for
    one rate must re-diagnose and re-tune when the rate steps.

    ``phases`` is a list of ``(duration_s, qps)`` segments; each phase
    emits its own seeded Poisson arrival schedule at that rate, offset to
    the phase start, all drawn from one workload (rids stay globally
    unique). Used by ``benchmarks/fig14_capacity.py`` to compare a static
    configuration against the capacity controller under load steps."""
    workload: SyntheticWorkload
    phases: List[tuple]           # (duration_s, qps) per phase
    seed: int = 0

    def requests(self) -> List[Request]:
        """Arrival-stamped requests across all phases, arrival-ordered."""
        arrs: List[np.ndarray] = []
        start = 0.0
        for k, (dur, qps) in enumerate(self.phases):
            if qps <= 0 or dur <= 0:
                start += max(0.0, dur)
                continue
            n = max(1, int(round(dur * qps)))
            a = poisson_arrivals(n, qps, seed=self.seed + 1000 * k,
                                 start=start)
            arrs.append(a[a < start + dur])
            start += dur
        if not arrs:
            return []
        arr = np.concatenate(arrs)
        return self.workload.build(len(arr), arrivals=arr)

    @property
    def n(self) -> int:
        return len(self.requests())

    @property
    def total_s(self) -> float:
        return float(sum(max(0.0, d) for d, _ in self.phases))

    @property
    def mean_qps(self) -> float:
        tot = self.total_s
        return self.n / tot if tot > 0 else 0.0

    def drive(self, scheduler, *, time_scale: float = 1.0) -> int:
        """Live submission on the phased schedule (open loop: never waits
        on completions). Returns how many submissions were accepted."""
        reqs = self.requests()
        t0 = time.perf_counter()
        accepted = 0
        for r in reqs:
            delay = r.arrival * time_scale - (time.perf_counter() - t0)
            if delay > 0:
                time.sleep(delay)
            accepted += bool(scheduler.submit(r))
        return accepted


@dataclass
class ClosedLoopGen:
    """Fixed-concurrency loop: ``concurrency`` requests in flight at all
    times; each completion releases the next submission."""
    workload: SyntheticWorkload
    concurrency: int
    n: int
    seed: int = 0
    _sem: threading.Semaphore = field(init=False, repr=False, default=None)

    def drive(self, scheduler) -> int:
        reqs = self.workload.build(self.n)
        self._sem = threading.Semaphore(self.concurrency)
        prev_done = scheduler.on_complete
        prev_drop = scheduler.on_drop

        def _release(completion):
            self._sem.release()
            if prev_done is not None:
                prev_done(completion)

        def _release_drop(rid):
            # a request that will never complete (shed, MCT-filtered) must
            # still return its permit or the loop wedges at `concurrency`
            # losses
            self._sem.release()
            if prev_drop is not None:
                prev_drop(rid)

        scheduler.on_complete = _release
        scheduler.on_drop = _release_drop
        accepted = 0
        for r in reqs:
            self._sem.acquire()
            if scheduler.submit(r):
                accepted += 1
            else:
                self._sem.release()    # rejected: no completion will come
        return accepted

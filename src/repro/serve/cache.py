"""Content-addressed result cache + in-flight request coalescing.

The paper's central serving finding (§5–6) — and what PR 2's replica sweep
reproduced — is that the *serial host prepare path* caps aggregate
throughput no matter how many accelerator replicas sit behind it. Travel-
search traffic is highly repetitive (the same origin/destination/date
query recurs within seconds), so the highest-leverage fix is to stop
re-encoding and re-executing identical work at all: the caching-near-the-
accelerator pattern that "Data Processing with FPGAs on Modern
Architectures" identifies as key to cost-effective deployments. Two
mechanisms, both content-addressed by :func:`request_key` (a canonical
hash of everything that determines a request's result — prompt tokens,
decode budget, MCT queries + connect times; never the rid or arrival
time):

- :class:`ResultCache` — completed results, TTL + byte-bounded LRU.
  A hit costs zero host encode and zero device time. Fully deterministic:
  eviction is strict LRU over insertion/touch order and TTL expiry is
  judged against the caller's clock (logical replay time in
  ``Server.serve``, pipeline time in ``AsyncScheduler``), so a seeded run
  always produces the same hit/miss/eviction sequence.
- :class:`Coalescer` — single-flight dedup of identical *concurrent*
  requests ahead of admission: the first request with a given key is the
  **leader** and flows through the pipeline; identical requests that
  arrive while it is in flight become **followers** that subscribe to its
  completion. Followers never occupy admission-queue space, so they can
  never be rejected, blocked, or shed independently of their leader — if
  the leader is shed (``shed_oldest``) or MCT-filtered, its followers are
  dropped with it, atomically.

Because every engine replica serves the same model and results are pure
functions of request content, a minted cache/coalesce completion is
bit-identical (tokens, truncated flag) to what re-executing the request
would have produced — which is what lets measured throughput climb
*above* the serial-host prepare cap without breaking the serving stack's
bit-identity guarantee.
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from repro.serve.config import Coercible
from repro.serve.engine import Completion, Request

# accounting overhead per entry (key, OrderedDict slot, dataclass) so a
# cache full of tiny completions still has a meaningful byte bound
_ENTRY_OVERHEAD = 96


def request_key(req: Request) -> str:
    """Canonical content hash of a request: everything that determines its
    result (prompt tokens, decode budget, MCT queries, connect times) and
    nothing that doesn't (rid, arrival time). Two requests with equal keys
    are interchangeable — the cache/coalescer substitutes one's result for
    the other's."""
    h = hashlib.blake2b(digest_size=16)
    h.update(np.ascontiguousarray(np.asarray(req.tokens, np.int64)).tobytes())
    h.update(int(req.max_new_tokens).to_bytes(8, "little", signed=True))
    for q in req.mct_queries:
        for k in sorted(q):
            h.update(str(k).encode())
            h.update(int(q[k]).to_bytes(8, "little", signed=True))
        h.update(b";")
    for m in req.connect_minutes:
        h.update(int(m).to_bytes(8, "little", signed=True))
    return h.hexdigest()


@dataclass
class CacheConfig(Coercible):
    """Serving-layer result cache knobs (attach to ``ServeConfig.cache``
    / ``SchedulerConfig.cache``; ``None`` keeps caching fully off and the
    serving stack bit-identical to its uncached behavior).

    ``max_bytes``       — resident-size bound; strict LRU eviction above it.
    ``ttl``             — seconds (in the caller's clock) before an entry
                          goes stale; ``None`` disables expiry.
    ``coalesce``        — single-flight dedup of identical in-flight
                          requests.
    ``negative_ttl``    — seconds to remember that a content key was
                          MCT-*filtered* (dropped by the engine without a
                          completion), so the same doomed content doesn't
                          re-encode and re-execute on its next arrival;
                          ``None`` disables negative caching.
    ``promote_on_shed`` — when ``shed_oldest`` evicts a coalescing leader,
                          promote its first follower to leader so the
                          flight survives and only one request's worth of
                          work is shed.
    ``max_affinity``    — bound on the replica-affinity tombstone map:
                          when a TTL-expired entry is evicted, the replica
                          that produced it survives as a tombstone hint so
                          ``hit_aware`` routing can send the recompute back
                          to the owning replica (0 disables tombstones).
    """
    max_bytes: int = 64 << 20
    ttl: Optional[float] = None
    coalesce: bool = True
    negative_ttl: Optional[float] = None
    promote_on_shed: bool = True
    max_affinity: int = 4096


@dataclass
class CachedResult:
    """One cached completion payload: the content-determined fields only
    (tokens, truncated, the batch size it was produced at), plus the
    replica that produced it (per-replica hit-rate accounting) and the
    byte/TTL accounting."""
    tokens: np.ndarray
    truncated: bool
    batch_size: int
    replica: Optional[int]
    stored_at: float
    nbytes: int

    @classmethod
    def of(cls, comp: Completion, *, replica: Optional[int] = None,
           now: float = 0.0) -> "CachedResult":
        toks = np.array(comp.tokens, np.int32, copy=True)
        return cls(tokens=toks, truncated=comp.truncated,
                   batch_size=comp.batch_size, replica=replica,
                   stored_at=now, nbytes=int(toks.nbytes) + _ENTRY_OVERHEAD)

    def mint(self, rid: int) -> Completion:
        """A completion for ``rid`` served from this entry: zero host
        encode, zero device time (prefill/decode report 0 ms)."""
        return Completion(rid=rid, tokens=self.tokens.copy(),
                          prefill_ms=0.0, decode_ms=0.0,
                          batch_size=self.batch_size,
                          truncated=self.truncated)


@dataclass
class NegativeResult:
    """A remembered *filtered* verdict: the engine's MCT feasibility check
    dropped this content without producing a completion, so re-submitting
    the same content within ``negative_ttl`` is doomed — the scheduler
    drops it at submit time, spending zero queue space, host encode, or
    device time. Lives in the same LRU as positive entries (a later real
    ``put`` under the key replaces it)."""
    stored_at: float
    nbytes: int = _ENTRY_OVERHEAD


class ResultCache:
    """Thread-safe content-addressed completion cache with TTL + strict
    byte-bounded LRU eviction. Shared across replicas (one instance per
    ``Server``, visible to every session and serve() call), so a result
    computed on any replica serves hits for all of them.

    The optional ``metrics`` argument on :meth:`get`/:meth:`put` forwards
    stale/eviction/bytes-resident events to that run's
    ``MetricsCollector``; the optional ``tracer``/``rid`` pair likewise
    emits ``cache_lookup``/``cache_store`` marks into that run's
    :class:`~repro.serve.trace.Tracer`. The cache also keeps its own
    lifetime :meth:`stats` since one cache may outlive many sessions.
    """

    def __init__(self, config: Union[None, bool, dict, CacheConfig] = None):
        self.cfg = CacheConfig.coerce(config) or CacheConfig()
        self._lock = threading.Lock()
        self._entries: "OrderedDict[str, CachedResult]" = OrderedDict()
        # replica-affinity tombstones: key -> replica that produced the
        # (since-expired) entry. A TTL expiry is not amnesia about *where*
        # the content lived — hit_aware routing reads these to send the
        # recompute back to the owning replica (LRU-bounded separately
        # from the byte budget; entries are two machine words)
        self._affinity: "OrderedDict[str, int]" = OrderedDict()
        self.bytes_resident = 0
        self._counts = {"hits": 0, "misses": 0, "stale": 0,
                        "evictions": 0, "stores": 0,
                        "negative_hits": 0, "negative_stores": 0,
                        "affinity_rehomes": 0}

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get(self, key: str, now: float, *, metrics=None, tracer=None,
            rid=None) -> Union[None, CachedResult, NegativeResult]:
        """Look up ``key`` at time ``now`` (caller's clock). Returns the
        entry (touching its LRU position) or None on miss/TTL expiry; a
        :class:`NegativeResult` means the content is known-filtered (its
        TTL is ``negative_ttl``). Misses are counted internally only — the
        caller decides whether a miss turns into an admitted leader (see
        AsyncScheduler.submit)."""
        outcome = None
        try:
            with self._lock:
                e = self._entries.get(key)
                if e is None:
                    self._counts["misses"] += 1
                    outcome = "miss"
                    return None
                negative = isinstance(e, NegativeResult)
                ttl = self.cfg.negative_ttl if negative else self.cfg.ttl
                if ttl is not None and now - e.stored_at > ttl:
                    del self._entries[key]
                    self.bytes_resident -= e.nbytes
                    self._counts["stale"] += 1
                    outcome = "stale"
                    if not negative and e.replica is not None:
                        # the result is stale but its *placement* is not:
                        # leave a tombstone so the recompute can be routed
                        # back to the replica that produced it
                        self._remember_affinity_locked(key, e.replica)
                    if metrics is not None:
                        metrics.on_cache("stale")
                        metrics.note_cache_bytes(self.bytes_resident,
                                                 len(self._entries))
                    return None
                self._entries.move_to_end(key)
                self._counts["negative_hits" if negative else "hits"] += 1
                outcome = "negative_hit" if negative else "hit"
                return e
        finally:
            if tracer is not None:
                tracer.mark("cache_lookup", now, rid=rid, outcome=outcome)

    def put(self, key: str, entry: CachedResult, *, metrics=None,
            tracer=None, rid=None) -> None:
        """Insert/replace ``key``, then evict strictly-LRU until the byte
        bound holds (an entry larger than ``max_bytes`` evicts itself)."""
        if tracer is not None:
            tracer.mark("cache_store", entry.stored_at, rid=rid)
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self.bytes_resident -= old.nbytes
            # a live entry is the authoritative owner record; any stale
            # tombstone for the key would shadow it after the next expiry
            self._affinity.pop(key, None)
            self._entries[key] = entry
            self.bytes_resident += entry.nbytes
            self._counts["stores"] += 1
            evicted = 0
            while self.bytes_resident > self.cfg.max_bytes and self._entries:
                _, e = self._entries.popitem(last=False)
                self.bytes_resident -= e.nbytes
                evicted += 1
            if evicted:
                self._counts["evictions"] += evicted
            if metrics is not None:
                if evicted:
                    metrics.on_cache("evictions", evicted)
                metrics.note_cache_bytes(self.bytes_resident,
                                         len(self._entries))

    def put_negative(self, key: str, now: float, *, metrics=None,
                     tracer=None, rid=None) -> bool:
        """Remember that ``key`` was MCT-filtered. No-op (returns False)
        unless ``negative_ttl`` is configured; shares the LRU/byte bound
        with positive entries."""
        if self.cfg.negative_ttl is None:
            return False
        if tracer is not None:
            tracer.mark("cache_store", now, rid=rid, negative=True)
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self.bytes_resident -= old.nbytes
            e = NegativeResult(stored_at=now)
            self._entries[key] = e
            self.bytes_resident += e.nbytes
            self._counts["negative_stores"] += 1
            evicted = 0
            while self.bytes_resident > self.cfg.max_bytes and self._entries:
                _, old = self._entries.popitem(last=False)
                self.bytes_resident -= old.nbytes
                evicted += 1
            if evicted:
                self._counts["evictions"] += evicted
            if metrics is not None:
                metrics.on_cache("negative_stores")
                if evicted:
                    metrics.on_cache("evictions", evicted)
                metrics.note_cache_bytes(self.bytes_resident,
                                         len(self._entries))
        return True

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._entries

    # -- replica affinity (hit_aware routing) --------------------------------
    def _remember_affinity_locked(self, key: str, replica: int) -> None:
        self._affinity.pop(key, None)
        self._affinity[key] = int(replica)
        while len(self._affinity) > max(0, self.cfg.max_affinity):
            self._affinity.popitem(last=False)

    def owner_hint(self, key: str) -> Optional[int]:
        """The replica whose result last covered ``key``: a live entry's
        producer, else the tombstone left behind by its TTL expiry. Pure
        lookup — never counts as a hit/miss, never touches LRU order (a
        routing probe must not keep an entry artificially fresh)."""
        with self._lock:
            e = self._entries.get(key)
            if isinstance(e, CachedResult) and e.replica is not None:
                return e.replica
            return self._affinity.get(key)

    def rehome(self, key: str, replica: int) -> None:
        """Move ``key``'s affinity to ``replica`` — called when hit_aware
        routing *spills* away from a straggling/overloaded owner, so
        subsequent recomputes of the same content follow the work to its
        new home instead of hammering the old one."""
        with self._lock:
            self._counts["affinity_rehomes"] += 1
            self._remember_affinity_locked(key, replica)

    def stats(self) -> Dict[str, int]:
        """Lifetime counters (across every session sharing this cache)."""
        with self._lock:
            return dict(self._counts, bytes_resident=self.bytes_resident,
                        entries=len(self._entries),
                        affinity_entries=len(self._affinity))


class Coalescer:
    """Single-flight table for identical concurrent requests.

    ``claim(key, rid)`` marks an admitted request as the in-flight leader
    for its content key; ``attach(key, req)`` registers a later identical
    request as a follower of that leader (returns the leader rid, or None
    when nothing is in flight / coalescing is disabled — the caller then
    admits it normally). ``resolve(rid)`` / ``fail(rid)`` retire a leader
    on completion / shed-or-drop, handing back its followers so the
    scheduler can mint their completions or drop them *with* the leader.

    With ``enabled=False`` the table still tracks rid -> key so completed
    leaders can fill the :class:`ResultCache`, but never coalesces.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._flights: Dict[str, Tuple[int, List[Request]]] = {}
        self._key_of: Dict[int, str] = {}

    def attach(self, key: str, req: Request) -> Optional[int]:
        if not self.enabled:
            return None
        with self._lock:
            flight = self._flights.get(key)
            if flight is None:
                return None
            flight[1].append(req)
            return flight[0]

    def claim(self, key: str, rid: int) -> None:
        with self._lock:
            self._key_of[rid] = key
            if self.enabled and key not in self._flights:
                self._flights[key] = (rid, [])

    def _retire(self, rid: int) -> Tuple[Optional[str], List[Request]]:
        with self._lock:
            key = self._key_of.pop(rid, None)
            if key is None:
                return None, []
            flight = self._flights.get(key)
            if flight is not None and flight[0] == rid:
                del self._flights[key]
                return key, flight[1]
            return key, []

    def resolve(self, rid: int) -> Tuple[Optional[str], List[Request]]:
        """Leader ``rid`` completed: returns (key, followers to mint)."""
        return self._retire(rid)

    def fail(self, rid: int) -> Tuple[Optional[str], List[Request]]:
        """Leader ``rid`` was shed/dropped: returns (key, followers to
        drop with it). The key is released so the next identical request
        becomes a fresh leader."""
        return self._retire(rid)

    def promote(self, rid: int) -> Optional[Request]:
        """Leader ``rid`` is about to be shed: promote its first follower
        to flight leader so the flight survives and only the old leader's
        single request is lost. Returns the promoted :class:`Request`
        (the caller re-admits it in the shed leader's place) or None when
        ``rid`` leads no flight / has no followers (the caller then sheds
        the whole flight via :meth:`fail`)."""
        with self._lock:
            key = self._key_of.get(rid)
            if key is None:
                return None
            flight = self._flights.get(key)
            if flight is None or flight[0] != rid or not flight[1]:
                return None
            followers = flight[1]
            promoted = followers.pop(0)
            self._flights[key] = (promoted.rid, followers)
            self._key_of[promoted.rid] = key
            del self._key_of[rid]
            return promoted

    def in_flight(self) -> int:
        with self._lock:
            return len(self._flights)

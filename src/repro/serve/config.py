"""Uniform config-field coercion for the serving subsystems.

Every optional subsystem of the serving stack — result cache, capacity
control, tracing — is switched on the same way on ``ServeConfig`` /
``SchedulerConfig``::

    ServeConfig(cache=True, capacity={"window_s": 0.1}, trace=True)

    None / False   -> off (the stack stays bit-identical to the
                      subsystem-free behavior)
    True           -> on, with the subsystem's default knobs
    dict           -> on, dict unpacked as the config's kwargs
    config object  -> on, used as-is

:func:`coerce` is the one implementation of that rule;
:class:`Coercible` mixes it in as the ``coerce`` classmethod that
``CacheConfig``/``CapacityConfig``/``TraceConfig`` expose (and that
``ServeConfig.__post_init__``/``SchedulerConfig.__post_init__`` apply),
so no subsystem ever grows its own subtly-different spelling.
"""
from __future__ import annotations

from typing import Optional, Type, TypeVar

C = TypeVar("C")
E = TypeVar("E")


def coerce_enum(enum_cls: Type[E], value: object, *, field: str) -> E:
    """Normalise one enum-valued config field (``BackpressurePolicy``,
    ``RoutingPolicy``): accepts the enum member or its string value, and
    raises the uniform error message listing the valid values — the enum
    sibling of :func:`coerce`, so every policy knob rejects typos the
    same way."""
    try:
        return enum_cls(value)
    except ValueError:
        valid = [m.value for m in enum_cls]
        raise ValueError(
            f"{field} must be one of {valid}, got {value!r}") from None


def coerce(cls: Type[C], value: object, *,
           field: Optional[str] = None) -> Optional[C]:
    """Normalise one config-field value to ``None`` (off) or a ``cls``
    instance: ``None``/``False`` -> off, ``True`` -> ``cls()`` defaults,
    ``dict`` -> ``cls(**value)``, ``cls`` instance -> itself. ``field``
    names the config field in the error message (defaults to the class
    name minus its ``Config`` suffix, lowercased)."""
    if value is None or value is False:
        return None
    if value is True:
        return cls()
    if isinstance(value, dict):
        return cls(**value)
    if isinstance(value, cls):
        return value
    name = field if field is not None \
        else cls.__name__.removesuffix("Config").lower()
    raise ValueError(
        f"{name} must be None/bool/dict/{cls.__name__}, got {value!r}")


class Coercible:
    """Mixin giving a config dataclass the shared ``coerce`` classmethod."""

    @classmethod
    def coerce(cls, value):
        """Normalise the config-field spellings: None/False -> off,
        True -> defaults, dict -> kwargs, instance -> itself."""
        return coerce(cls, value)

"""Static cost analysis of post-optimization HLO text.

Why not ``compiled.cost_analysis()``? XLA's HloCostAnalysis counts while-loop
bodies ONCE, ignoring trip counts — a framework built on ``lax.scan`` (layer
stacks, blockwise attention, SSM chunk scans) would be undercounted by 10-500x.
This analyzer:

- multiplies while bodies by their ``known_trip_count`` (backend_config),
  falling back to the loop-condition constant;
- counts dot FLOPs from contracting/batch dims;
- counts HBM traffic at fusion granularity (fusion operands + result; fused
  internals are free) — closer to real memory behaviour than per-op sums;
- extracts per-collective byte volumes and ring-model wire costs, the input
  to the collective roofline term.

Cross-validated against compiled.cost_analysis() on loop-free programs
(tests/test_hlo_analysis.py).
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "u1": 1,
    "s1": 1, "f4e2m1fn": 0.5, "f8e8m0fnu": 1,
}

_ZERO_COST = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
    "rng-bit-generator", "rng", "opt-barrier", "domain", "custom-call",
    "get-dimension-size",
}
_MOVE_ONLY = {
    "copy", "convert", "broadcast", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "pad", "transpose", "gather",
    "scatter", "reverse", "reduce-window", "select-and-scatter", "sort",
    "copy-start", "copy-done",
}
_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INST_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[^\s=]+)\s+=\s+(?P<rest>.*)$")
_OP_RE = re.compile(r"^(?P<shape>.*?)\s(?P<op>[a-z][\w\-]*)\(")


def _shape_bytes_elems(shape_str: str) -> Tuple[float, float]:
    """(bytes, elements) of a possibly-tuple shape string."""
    total_b = total_e = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1.0
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_b, total_e


@dataclass
class Inst:
    name: str
    op: str
    shape: str
    args: str
    attrs: str
    operands: List[str] = field(default_factory=list)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_wire: float = 0.0  # ring-model bytes-on-wire per device
    by_cat: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_wire += other.coll_wire * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.by_cat.items():
            self.by_cat[k] = self.by_cat.get(k, 0.0) + v * mult


def _split_args(rest: str) -> Tuple[str, str]:
    """rest starts right after 'op(' — split top-level args vs attrs."""
    depth, i = 1, 0
    while i < len(rest) and depth:
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
        i += 1
    return rest[: i - 1], rest[i:]


def parse_hlo(text: str) -> Tuple[Dict[str, List[Inst]], str]:
    """computation name -> instructions; plus the ENTRY computation name."""
    comps: Dict[str, List[Inst]] = {}
    entry = ""
    cur: Optional[str] = None
    for line in text.splitlines():
        s = line.rstrip()
        if not s:
            continue
        if s.endswith("{") and ("(" in s) and "=" not in s.split("(")[0]:
            head = s.strip()
            is_entry = head.startswith("ENTRY")
            head = head[5:].strip() if is_entry else head
            name = head.split()[0].lstrip("%")
            cur = name
            comps[cur] = []
            if is_entry:
                entry = name
            continue
        if s.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INST_RE.match(s)
        if not m or "=" not in s:
            continue
        rest = m.group("rest")
        om = _OP_RE.match(rest)
        if not om:
            continue
        shape, op = om.group("shape").strip(), om.group("op")
        tail = rest[om.end():]
        args, attrs = _split_args(tail)
        operands = re.findall(r"%([\w\.\-]+)", args)
        comps[cur].append(Inst(name=m.group("name").lstrip("%"), op=op,
                               shape=shape, args=args, attrs=attrs,
                               operands=operands))
    return comps, entry


def _trip_count(inst: Inst, comps) -> float:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"', inst.attrs)
    if m:
        return float(m.group(1))
    # fallback: constant in the condition computation
    cm = re.search(r"condition=%?([\w\.\-]+)", inst.attrs)
    if cm and cm.group(1) in comps:
        for ci in comps[cm.group(1)]:
            k = re.search(r"constant\((\d+)\)", ci.shape + " " +
                          ci.op + "(" + ci.args + ")" + ci.attrs)
            if ci.op == "constant":
                k = re.search(r"\((\d+)\)", "(" + ci.args + ")")
                if k:
                    return float(k.group(1))
    return 1.0


def _group_size(attrs: str, num_partitions: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return num_partitions


class HloCostModel:
    def __init__(self, text: str):
        self.comps, self.entry = parse_hlo(text)
        m = re.search(r"num_partitions=(\d+)", text)
        self.num_partitions = int(m.group(1)) if m else 1
        self._memo: Dict[Tuple[str, bool], Cost] = {}

    def _fusion_bytes(self, inst: Inst, shapes, fcomp: str,
                      out_b: float, opnd_b: float) -> float:
        """Slice-aware HBM traffic of a fusion call site.

        - a fused-computation parameter consumed ONLY by dynamic-slice /
          gather reads just the slices, not the whole buffer (loop-carry
          reads in scans);
        - a root dynamic-update-slice writes (and reads) just the update
          region, in place (loop-carry writes in scans).
        """
        insts = self.comps.get(fcomp, [])
        if not insts:
            return out_b + opnd_b
        by_name = {i.name: i for i in insts}
        uses: Dict[str, List[Inst]] = {}
        for i in insts:
            for o in i.operands:
                uses.setdefault(o, []).append(i)
        total = 0.0
        # effective read bytes per parameter
        params = [i for i in insts if i.op == "parameter"]
        for pi, p in enumerate(params):
            full = _shape_bytes_elems(p.shape)[0]
            us = uses.get(p.name, [])
            if us and all(u.op in ("dynamic-slice", "gather", "slice")
                          and u.operands and u.operands[0] == p.name
                          for u in us):
                eff = sum(_shape_bytes_elems(u.shape)[0] * (2 if
                          u.op == "gather" else 1) for u in us)
                total += min(eff, full)
            else:
                total += full
        # effective write bytes at the root
        root = insts[-1]
        roots = [root]
        if root.op == "tuple":
            roots = [by_name[o] for o in root.operands if o in by_name]
        for r in roots:
            if r.op == "dynamic-update-slice" and len(r.operands) > 1:
                upd = by_name.get(r.operands[1])
                upd_b = _shape_bytes_elems(upd.shape)[0] if upd is not None \
                    else _shape_bytes_elems(r.shape)[0]
                # in-place: write the update region only; the buffer read
                # was already charged via its parameter (full or sliced)
                buf = by_name.get(r.operands[0])
                if buf is not None and buf.op == "parameter":
                    total -= max(_shape_bytes_elems(buf.shape)[0] - upd_b,
                                 0.0)
                total += upd_b
            else:
                total += _shape_bytes_elems(r.shape)[0]
        return total

    def total(self) -> Cost:
        return self._comp_cost(self.entry, fused=False)

    # -- internals -------------------------------------------------------
    def _comp_cost(self, name: str, fused: bool) -> Cost:
        key = (name, fused)
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        shapes = {i.name: i.shape for i in self.comps.get(name, [])}
        for inst in self.comps.get(name, []):
            total.add(self._inst_cost(inst, shapes, fused))
        self._memo[key] = total
        return total

    def _inst_cost(self, inst: Inst, shapes: Dict[str, str],
                   fused: bool) -> Cost:
        c = Cost()
        op = inst.op
        out_b, out_e = _shape_bytes_elems(inst.shape)
        opnd_b = sum(_shape_bytes_elems(shapes.get(o, ""))[0]
                     for o in inst.operands)

        if op == "while":
            bm = re.search(r"body=%?([\w\.\-]+)", inst.attrs)
            cm = re.search(r"condition=%?([\w\.\-]+)", inst.attrs)
            trips = _trip_count(inst, self.comps)
            if bm:
                c.add(self._comp_cost(bm.group(1), fused=False), trips)
            if cm:
                c.add(self._comp_cost(cm.group(1), fused=False), trips)
            return c
        if op == "conditional":
            for br in re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                 r"(?:true|false)_computation=%?([\w\.\-]+))",
                                 inst.attrs):
                for b in br:
                    for nm in re.findall(r"%?([\w\.\-]+)", b or ""):
                        if nm in self.comps:
                            c.add(self._comp_cost(nm, fused=False))
            return c
        if op == "fusion":
            fm = re.search(r"calls=%?([\w\.\-]+)", inst.attrs)
            if fm:
                inner = self._comp_cost(fm.group(1), fused=True)
                c.flops += inner.flops
                c.coll_wire += inner.coll_wire
                for k, v in inner.coll_bytes.items():
                    c.coll_bytes[k] = c.coll_bytes.get(k, 0.0) + v
                for k, v in inner.by_cat.items():
                    c.by_cat[k] = c.by_cat.get(k, 0.0) + v
                c.bytes += self._fusion_bytes(inst, shapes, fm.group(1),
                                              out_b, opnd_b)
            else:
                c.bytes += out_b + opnd_b
            return c
        if op == "call":
            fm = re.search(r"to_apply=%?([\w\.\-]+)", inst.attrs)
            if fm:
                c.add(self._comp_cost(fm.group(1), fused=False))
            return c

        if op in _COLLECTIVES:
            kind = op.replace("-start", "")
            n = _group_size(inst.attrs, self.num_partitions)
            size = max(out_b, opnd_b)
            c.coll_bytes[kind] = c.coll_bytes.get(kind, 0.0) + size
            ring = (n - 1) / max(n, 1)
            if kind == "all-reduce":
                wire = 2.0 * opnd_b * ring
            elif kind == "all-gather":
                wire = out_b * ring
            elif kind == "reduce-scatter":
                wire = opnd_b * ring
            elif kind == "all-to-all":
                wire = opnd_b * ring
            else:  # collective-permute
                wire = opnd_b
            c.coll_wire += wire
            c.bytes += out_b + opnd_b if not fused else 0.0
            c.by_cat["collective"] = c.by_cat.get("collective", 0.0) + size
            return c

        if op == "dot":
            lhs_shape = shapes.get(inst.operands[0], "") if inst.operands \
                else ""
            _, lhs_e = _shape_bytes_elems(lhs_shape)
            cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                              inst.attrs)
            k = 1.0
            if cdims and lhs_shape:
                dims_m = _SHAPE_RE.search(lhs_shape)
                if dims_m and dims_m.group(2):
                    lhs_dims = [int(d) for d in dims_m.group(2).split(",")]
                    for ci in cdims.group(1).split(","):
                        if ci != "":
                            k *= lhs_dims[int(ci)]
            flops = 2.0 * out_e * k
            c.flops += flops
            c.by_cat["dot"] = c.by_cat.get("dot", 0.0) + flops
            if not fused:
                c.bytes += out_b + opnd_b
            return c

        if op in _ZERO_COST:
            if op == "custom-call" and not fused:
                c.bytes += out_b + opnd_b
            return c
        if op in _MOVE_ONLY:
            if not fused:
                if op == "dynamic-update-slice" and inst.operands:
                    upd_b = _shape_bytes_elems(
                        shapes.get(inst.operands[1], ""))[0] \
                        if len(inst.operands) > 1 else out_b
                    c.bytes += 2 * upd_b  # in-place: update read + write
                elif op in ("dynamic-slice", "gather"):
                    c.bytes += 2 * out_b  # read slice + write result
                else:
                    c.bytes += out_b + opnd_b
            return c

        # default: elementwise / reduce / compare / select ...
        if op == "reduce":
            in_b, in_e = _shape_bytes_elems(
                shapes.get(inst.operands[0], "")) if inst.operands \
                else (out_b, out_e)
            c.flops += in_e
            c.by_cat["reduce"] = c.by_cat.get("reduce", 0.0) + in_e
        else:
            c.flops += out_e
            cat = ("transcendental" if op in
                   ("exponential", "tanh", "log", "power", "rsqrt", "sqrt",
                    "divide", "expm1", "log1p", "logistic", "cosine", "sine",
                    "atan2", "erf")
                   else "elementwise")
            c.by_cat[cat] = c.by_cat.get(cat, 0.0) + out_e
        if not fused:
            c.bytes += out_b + opnd_b
        return c


def collective_table(text: str, top: int = 15) -> List[dict]:
    """Attribute collective wire bytes to source ops (metadata op_name),
    with while-loop trip-count multiplication. The dry-run 'profiler' used
    by the §Perf iteration loop."""
    model = HloCostModel(text)
    return _attribute(model, _trip_multipliers(model), top, metric="wire")


def bytes_table(text: str, top: int = 15) -> List[dict]:
    """Attribute HBM-traffic bytes to source ops (trip-count aware)."""
    model = HloCostModel(text)
    mult = _trip_multipliers(model)
    return _attribute(model, mult, top, metric="bytes")


def _trip_multipliers(model: "HloCostModel") -> Dict[str, float]:
    comps = model.comps
    mult: Dict[str, float] = {}

    def walk(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for inst in comps[name]:
            if inst.op == "while":
                t = _trip_count(inst, comps)
                for key in ("body", "condition"):
                    mm = re.search(key + r"=%?([\w\.\-]+)", inst.attrs)
                    if mm:
                        walk(mm.group(1), m * t)
            elif inst.op == "call":
                mm = re.search(r"to_apply=%?([\w\.\-]+)", inst.attrs)
                if mm:
                    walk(mm.group(1), m)
            # fusions are costed at the call site; do not walk into them

    walk(model.entry, 1.0)
    return mult


def _attribute(model: "HloCostModel", mult: Dict[str, float], top: int,
               metric: str) -> List[dict]:
    comps = model.comps
    rows: Dict[Tuple[str, str, str], dict] = {}
    for cname, insts in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        shapes = {i.name: i.shape for i in insts}
        for inst in insts:
            if metric == "wire":
                if inst.op not in _COLLECTIVES:
                    continue
                out_b, _ = _shape_bytes_elems(inst.shape)
                opnd_b = sum(_shape_bytes_elems(shapes.get(o, ""))[0]
                             for o in inst.operands)
                n = _group_size(inst.attrs, model.num_partitions)
                ring = (n - 1) / max(n, 1)
                kind = inst.op.replace("-start", "")
                val = {"all-reduce": 2 * opnd_b * ring,
                       "all-gather": out_b * ring,
                       "reduce-scatter": opnd_b * ring,
                       "all-to-all": opnd_b * ring}.get(kind, opnd_b)
            else:
                if inst.op in ("while", "call", "conditional"):
                    continue  # contents attributed via trip multipliers
                # per-instruction HBM bytes via the same model as totals
                c = model._inst_cost(inst, shapes, False)
                val = c.bytes
                if val <= 0:
                    continue
                kind = inst.op
                n = model.num_partitions
            om = re.search(r'op_name="([^"]*)"', inst.attrs)
            src = om.group(1) if om else "?"
            src = re.sub(r"/while/body", "", src)[:90]
            key = (kind, src, f"g{n}")
            r = rows.setdefault(key, {"kind": kind, "src": src, "group": n,
                                      "wire": 0.0, "count": 0.0})
            r["wire"] += val * m
            r["count"] += m
    out = sorted(rows.values(), key=lambda r: -r["wire"])
    return out[:top]


def analyze_text(text: str) -> Dict[str, object]:
    model = HloCostModel(text)
    t = model.total()
    return {
        "flops": t.flops,
        "bytes": t.bytes,
        "collective_bytes": dict(t.coll_bytes),
        "collective_wire_bytes": t.coll_wire,
        "by_category": dict(t.by_cat),
        "num_partitions": model.num_partitions,
    }

"""Roofline terms from dry-run artifacts.

TPU v5e hardware model (per the assignment):
  197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.

  compute term    = HLO_FLOPs(per device) / peak_FLOPs
  memory term     = HLO_bytes(per device) / HBM_bw
  collective term = ring-model wire bytes(per device) / link_bw

HLO_FLOPs/bytes come from the repro HLO analyzer (hlo_analysis.py), which —
unlike compiled.cost_analysis() — multiplies while-loop bodies by their trip
counts (see tests/test_hlo_analysis.py).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s
LINK_BW = 50e9           # B/s per ICI link


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    hlo_bytes: float
    coll_wire_bytes: float
    n_devices: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Lower-bound step time (no overlap assumed = max of terms;
        perfect overlap would be max, serial would be sum — report max)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def usefulness(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global): remat/redundancy waste."""
        total_hlo = self.hlo_flops * self.n_devices
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilisation upper bound at the roofline step time."""
        denom = self.step_s * PEAK_FLOPS * self.n_devices
        return self.model_flops / denom if denom else 0.0


def from_record(rec: dict) -> Optional[Roofline]:
    if not rec.get("ok"):
        return None
    h = rec["hlo"]
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=h["flops"] / PEAK_FLOPS,
        memory_s=h["bytes"] / HBM_BW,
        collective_s=h["collective_wire_bytes"] / LINK_BW,
        model_flops=rec["model_flops"],
        hlo_flops=h["flops"], hlo_bytes=h["bytes"],
        coll_wire_bytes=h["collective_wire_bytes"],
        n_devices=rec["n_devices"])


def load_all(art_dir, variant: Optional[str] = "") -> List[Roofline]:
    """variant="" -> baseline records only; None -> everything."""
    out = []
    for p in sorted(Path(art_dir).glob("*.json")):
        rec = json.loads(p.read_text())
        if variant is not None and rec.get("variant", "") != variant:
            continue
        r = from_record(rec)
        if r is not None:
            out.append(r)
    return out


def table_markdown(rows: List[Roofline]) -> str:
    hdr = ("| arch | shape | mesh | compute(s) | memory(s) | collective(s) "
           "| dominant | MODEL/HLO | MFU-bound |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.4g} "
                 f"| {r.memory_s:.4g} | {r.collective_s:.4g} "
                 f"| **{r.dominant}** | {r.usefulness:.2f} "
                 f"| {r.mfu_bound:.3f} |\n")
    return hdr + body

"""Production meshes.

Functions, not module-level constants — importing this module never touches
jax device state. The dry-run process sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any import.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(n_devices: int, model_parallel: int = 0):
    """Best-effort (data, model) mesh over n_devices (tests, small runs)."""
    if model_parallel <= 0:
        model_parallel = 1
        for cand in (16, 8, 4, 2):
            if n_devices % cand == 0 and n_devices >= cand:
                model_parallel = cand
                break
    return jax.make_mesh((n_devices // model_parallel, model_parallel),
                         ("data", "model"))


def batch_axes_of(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)

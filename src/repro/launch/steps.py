"""Step builders shared by the dry-run, the trainer and the server:
microbatched (grad-accumulation) train step, prefill step, decode step —
each with full in/out shardings and donation.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.launch.mesh import batch_axes_of
from repro.models.registry import Model, build_model, make_inputs
from repro.sharding.specs import (ShardCtx, cache_shardings, param_shardings,
                                  param_specs)
from repro.train.optimizer import AdamW, AdamWState


def make_ctx(mesh, cell: Optional[ShapeCell], cfg: ModelConfig) -> ShardCtx:
    """ShardCtx for a (mesh, shape-cell): decode/prefill cells get
    sequence-sharded KV caches when kv-heads don't divide the model axis."""
    baxes = batch_axes_of(mesh)
    seq_axes = None
    if cell is not None and cell.kind in ("prefill", "decode"):
        if cell.global_batch == 1:
            seq_axes = ("data", "model")
        elif cfg.n_kv_heads % mesh.shape["model"] != 0:
            seq_axes = ("model",)
    return ShardCtx(mesh=mesh, batch_axes=baxes, fsdp_axis="data",
                    model_axis="model", cache_seq_axes=seq_axes)


def abstract_params(model: Model):
    return jax.eval_shape(lambda k: model.init(k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def microbatches_for(cfg: ModelConfig, cell: ShapeCell, mesh,
                     batch_axes=None) -> int:
    """Largest M <= cfg.train_microbatches with (B/M) divisible by dp."""
    axes = batch_axes or batch_axes_of(mesh)
    dp = int(np.prod([mesh.shape[a] for a in axes]))
    m = min(cfg.train_microbatches, max(cell.global_batch // dp, 1))
    while m > 1 and (cell.global_batch % m or
                     (cell.global_batch // m) % dp):
        m -= 1
    return max(m, 1)


def build_train_step(model: Model, ctx: ShardCtx, opt: AdamW,
                     n_microbatches: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    cfg = model.cfg
    accum_dtype = jnp.float32 if cfg.optimizer_dtype == "float32" \
        else jnp.bfloat16

    def constrain_batch(tree):
        def one(t):
            b = ctx.maybe(t.shape[0], ctx.batch_axes)
            spec = P(*([b] + [None] * (t.ndim - 1)))
            return jax.lax.with_sharding_constraint(
                t, NamedSharding(ctx.mesh, spec))
        return jax.tree_util.tree_map(one, tree)

    def train_step(params, opt_state: AdamWState, batch):
        M = n_microbatches

        def loss_fn(p, mb):
            return model.loss(p, mb)

        if M == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)
        else:
            mbs = jax.tree_util.tree_map(
                lambda t: t.reshape((M, t.shape[0] // M) + t.shape[1:]),
                batch)

            def mb_body(acc, mb):
                g_acc, l_acc = acc
                mb = constrain_batch(mb)
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + loss), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (g_sum, loss_sum), _ = jax.lax.scan(
                mb_body, (zeros, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32) / M, g_sum)
            loss = loss_sum / M

        new_p, new_s, gnorm = opt.update(grads, opt_state, params)
        return new_p, new_s, {"loss": loss, "grad_norm": gnorm}

    return train_step


def opt_state_shardings(pshard, mesh):
    return AdamWState(
        step=NamedSharding(mesh, P()),
        mu=pshard, nu=pshard)


def jit_train_step(model: Model, ctx: ShardCtx, opt: AdamW,
                   batch_struct, n_microbatches: int = 1,
                   zero1: bool = False):
    """zero1=True: params replicated over the data axis (TP only), optimizer
    states FSDP-sharded — removes the per-microbatch weight all-gathers of
    ZeRO-3 at the cost of one param all-gather per step. Wins when params
    are small relative to the per-step gather traffic (e.g. gemma3-1b)."""
    import dataclasses

    pstruct = abstract_params(model)
    pshard = param_shardings(pstruct, model.cfg, ctx)
    if zero1:
        ctx_nofsdp = dataclasses.replace(ctx, fsdp_axis=None)
        oshard = opt_state_shardings(pshard, ctx.mesh)
        pshard = param_shardings(pstruct, model.cfg, ctx_nofsdp)
    else:
        oshard = opt_state_shardings(pshard, ctx.mesh)
    bshard = ctx.batch_spec(batch_struct)
    mshard = {"loss": NamedSharding(ctx.mesh, P()),
              "grad_norm": NamedSharding(ctx.mesh, P())}
    step = build_train_step(model, ctx, opt, n_microbatches)
    jitted = jax.jit(step,
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, mshard),
                     donate_argnums=(0, 1))
    ostruct = jax.eval_shape(opt.init, pstruct)
    return jitted, (pstruct, ostruct, pshard, oshard)


def jit_prefill(model: Model, ctx: ShardCtx, batch_struct):
    pstruct = abstract_params(model)
    pshard = param_shardings(pstruct, model.cfg, ctx)
    bshard = ctx.batch_spec(batch_struct)
    jitted = jax.jit(lambda p, b: model.prefill(p, b),
                     in_shardings=(pshard, bshard))
    return jitted, (pstruct, pshard)


def jit_decode(model: Model, ctx: ShardCtx, batch: int, seq_len: int):
    pstruct = abstract_params(model)
    pshard = param_shardings(pstruct, model.cfg, ctx)
    cstruct = model.cache_struct(batch, seq_len)
    cshard = cache_shardings(cstruct, model.cfg, ctx)
    tok_sh = NamedSharding(
        ctx.mesh, P(ctx.maybe(batch, ctx.batch_axes), None))
    pos_sh = NamedSharding(ctx.mesh, P())
    lg_sh = NamedSharding(
        ctx.mesh, P(ctx.maybe(batch, ctx.batch_axes), None,
                    ctx.maybe(model.cfg.vocab, ctx.model_axis)))
    jitted = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos),
                     in_shardings=(pshard, cshard, tok_sh, pos_sh),
                     out_shardings=(lg_sh, cshard),
                     donate_argnums=(1,))
    tok_struct = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
    return jitted, (pstruct, cstruct, tok_struct, pos_struct)


def cell_batch_struct(cfg: ModelConfig, cell: ShapeCell):
    b = make_inputs(cfg, cell.global_batch, cell.seq_len, abstract=True)
    if cell.kind == "prefill" and not cfg.encoder_only:
        b.pop("labels", None)
    return b

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("REPRO_DRYRUN_DEVICES"):  # tests: shrink the fake fleet
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes (16x16 single-pod; 2x16x16 multi-pod), record
memory_analysis, cost_analysis, and the HLO-derived roofline inputs.

MUST be run as its own process (the two lines above lock jax's device count
before any other import). Results land in artifacts/dryrun/<cell>.json.

Usage:
  python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ASSIGNED_ARCHS, SHAPES_BY_NAME, ShapeCell,
                                get_config)
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (cell_batch_struct, jit_decode, jit_prefill,
                                jit_train_step, make_ctx, microbatches_for)
from repro.models.registry import build_model
from repro.sharding.specs import param_specs
from repro.train.optimizer import AdamW

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _variant_suffix() -> str:
    v = os.environ.get("REPRO_VARIANT", "")
    return f"__{v}" if v else ""


def _mem_stats(compiled):
    out = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                out[k] = int(v)
    except Exception as e:  # pragma: no cover
        out["error"] = str(e)
    return out


def _analytic_param_bytes(pstruct, cfg, ctx) -> float:
    """Per-device parameter bytes under the sharding policy."""
    specs = param_specs(pstruct, cfg, ctx)
    total = 0.0
    for sds, spec in zip(jax.tree_util.tree_leaves(pstruct),
                         jax.tree_util.tree_leaves(
                             specs, is_leaf=lambda x: isinstance(
                                 x, jax.sharding.PartitionSpec))):
        shards = 1
        for ax in spec:
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                shards *= ctx.mesh.shape[a]
        total += sds.size * sds.dtype.itemsize / shards
    return total


def model_flops_for(cfg, cell: ShapeCell) -> float:
    n_act = cfg.n_active_params()
    if cell.kind == "train":
        return 6.0 * n_act * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n_act * cell.global_batch * cell.seq_len
    return 2.0 * n_act * cell.global_batch  # decode: one token per sequence


def _mesh_for(multi_pod: bool):
    spec = os.environ.get("REPRO_DRYRUN_MESH")
    if spec:  # tests: e.g. "2x2" or "2x2x2"
        dims = tuple(int(d) for d in spec.split("x"))
        axes = ("pod", "data", "model")[-len(dims):]
        return jax.make_mesh(dims, axes)
    return make_production_mesh(multi_pod=multi_pod)


def run_cell(arch: str, shape: str, multi_pod: bool) -> dict:
    import dataclasses

    cfg = get_config(arch)
    cell = SHAPES_BY_NAME[shape]
    mesh = _mesh_for(multi_pod)
    ctx = make_ctx(mesh, cell, cfg)
    # perf-variant knobs (EXPERIMENTS.md §Perf); default = paper-faithful
    zero1 = os.environ.get("REPRO_ZERO1") == "1"
    if os.environ.get("REPRO_MOE_WS") == "1":
        ctx = dataclasses.replace(ctx, moe_weight_stationary=True)
    if os.environ.get("REPRO_QBLOCK") == "1":
        ctx = dataclasses.replace(ctx, attn_qblock=True)
    if os.environ.get("REPRO_SLSTM_LG") == "1":
        ctx = dataclasses.replace(ctx, slstm_local_grad=True)
    if os.environ.get("REPRO_DP_ONLY") == "1":
        # right-size parallelism: the model axis joins data parallelism —
        # no tensor sharding (small models on a fixed wide mesh)
        ctx = dataclasses.replace(
            ctx, batch_axes=tuple(ctx.batch_axes) + ("model",),
            model_axis=None)
    if os.environ.get("REPRO_SSM_CHUNK_LOCAL") == "1" and cfg.ssm:
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, chunk_local=True))
    model = build_model(cfg, ctx)
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "kind": cell.kind, "ok": False,
        "variant": os.environ.get("REPRO_VARIANT", ""),
    }
    t0 = time.time()
    with mesh:
        if cell.kind == "train":
            opt = AdamW(
                state_dtype=jnp.bfloat16
                if cfg.optimizer_dtype == "bfloat16" else jnp.float32,
                total_steps=10_000)
            nmb = microbatches_for(cfg, cell, mesh,
                                   batch_axes=ctx.batch_axes)
            rec["microbatches"] = nmb
            batch = cell_batch_struct(cfg, cell)
            jitted, (pstruct, ostruct, pshard, _) = jit_train_step(
                model, ctx, opt, batch, nmb, zero1=zero1)
            lowered = jitted.lower(pstruct, ostruct, batch)
        elif cell.kind == "prefill":
            batch = cell_batch_struct(cfg, cell)
            jitted, (pstruct, pshard) = jit_prefill(model, ctx, batch)
            lowered = jitted.lower(pstruct, batch)
        else:
            jitted, (pstruct, cstruct, tok, pos) = jit_decode(
                model, ctx, cell.global_batch, cell.seq_len)
            lowered = jitted.lower(pstruct, cstruct, tok, pos)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    rec["memory"] = _mem_stats(compiled)
    try:
        ca = compiled.cost_analysis()
        rec["xla_cost"] = {"flops": float(ca.get("flops", 0.0)),
                           "bytes": float(ca.get("bytes accessed", 0.0))}
    except Exception as e:
        rec["xla_cost"] = {"error": str(e)}
    hlo_text = compiled.as_text()
    rec["hlo"] = hlo_analysis.analyze_text(hlo_text)
    if os.environ.get("REPRO_SAVE_HLO", "1") == "1":
        import gzip
        ART.mkdir(parents=True, exist_ok=True)
        tag = (f"{arch}__{shape}__"
               f"{'multi' if multi_pod else 'single'}{_variant_suffix()}")
        with gzip.open(ART / (tag + ".hlo.gz"), "wt") as f:
            f.write(hlo_text)
    rec["model_flops"] = model_flops_for(cfg, cell)
    rec["param_bytes_per_device"] = _analytic_param_bytes(
        jax.eval_shape(lambda k: model.init(k),
                       jax.ShapeDtypeStruct((2,), jnp.uint32)), cfg, ctx)
    rec["n_params"] = cfg.n_params()
    rec["n_active_params"] = cfg.n_active_params()
    rec["ok"] = True
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(ART))
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    cells = []
    archs = ASSIGNED_ARCHS if args.all or not args.arch else [args.arch]
    for arch in archs:
        cfg = get_config(arch)
        shapes = [c.name for c in cfg.shape_cells()]
        if args.shape:
            shapes = [s for s in shapes if s == args.shape]
        for s in shapes:
            if args.mesh in ("single", "both"):
                cells.append((arch, s, False))
            if args.mesh in ("multi", "both"):
                cells.append((arch, s, True))

    n_ok = 0
    for arch, shape, mp in cells:
        tag = (f"{arch}__{shape}__{'multi' if mp else 'single'}"
               f"{_variant_suffix()}")
        path = out_dir / (tag + ".json")
        try:
            rec = run_cell(arch, shape, mp)
        except Exception as e:
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16", "ok": False,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        path.write_text(json.dumps(rec, indent=1))
        status = "OK" if rec.get("ok") else "FAIL"
        print(f"[{status}] {tag} "
              f"compile={rec.get('compile_s', '-')}s "
              f"flops={rec.get('hlo', {}).get('flops', 0):.3e}"
              if rec.get("ok") else f"[{status}] {tag}: "
              f"{rec.get('error', '')[:200]}", flush=True)
        n_ok += bool(rec.get("ok"))
    print(f"dry-run: {n_ok}/{len(cells)} cells OK")
    return 0 if n_ok == len(cells) else 1


if __name__ == "__main__":
    raise SystemExit(main())

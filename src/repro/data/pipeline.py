"""Deterministic synthetic data pipeline with sharded, resumable iteration.

Tokens are a pure function of (seed, step, position) via a counter-based
threefry hash, so: (a) every data-parallel shard generates ONLY its slice —
no host reads the global batch; (b) restart-from-checkpoint resumes the
stream exactly (the step index is the cursor); (c) no filesystem dependency.
A background prefetch thread keeps `depth` batches ready (host-side input
pipelining — the paper's encode/execute overlap, applied to training).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


def _threefry_like(x: np.ndarray, seed: int) -> np.ndarray:
    """Cheap counter-based hash (splitmix-ish), vectorised uint64 -> uint64."""
    mix = (seed * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    z = (x.astype(np.uint64) + np.uint64(mix)) \
        * np.uint64(0xBF58476D1CE4E5B9)
    z ^= z >> np.uint64(27)
    z *= np.uint64(0x94D049BB133111EB)
    z ^= z >> np.uint64(31)
    return z


@dataclass
class ShardSpec:
    shard_id: int = 0
    n_shards: int = 1


def synth_batch(cfg: ModelConfig, step: int, batch: int, seq_len: int,
                seed: int = 0, shard: ShardSpec = ShardSpec()
                ) -> Dict[str, np.ndarray]:
    """The shard's slice of the global batch at `step`."""
    rows = batch // shard.n_shards
    row0 = shard.shard_id * rows
    # counter grid: (row, pos) -> global unique counter
    r = (np.arange(rows) + row0)[:, None].astype(np.uint64)
    p = np.arange(seq_len)[None, :].astype(np.uint64)
    ctr = (np.uint64(step) << np.uint64(40)) + (r << np.uint64(20)) + p
    h = _threefry_like(ctr, seed)
    out: Dict[str, np.ndarray] = {}
    if cfg.embedding_inputs:
        # frame embeddings: hash -> gaussian-ish floats via CLT of 2 uniforms
        d = cfg.d_model
        cols = np.arange(d)[None, None, :].astype(np.uint64)
        hh = _threefry_like(ctr[..., None] * np.uint64(131) + cols, seed + 1)
        u = (hh >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        out["embeds"] = ((u - 0.5) * 3.46).astype(np.float32)
        out["labels"] = (h % np.uint64(cfg.vocab)).astype(np.int32)
    else:
        # learnable structure: arithmetic token sequences with hash-derived
        # per-row offset/stride + 1/8 random-noise positions (so loss can
        # drop well below log(vocab) but not to zero)
        row_h = _threefry_like(r + np.uint64(step) * np.uint64(1 << 20),
                               seed + 3)
        offset = (row_h % np.uint64(cfg.vocab)).astype(np.int64)
        stride = (row_h >> np.uint64(17)) % np.uint64(2) + np.uint64(1)
        base = (offset + p.astype(np.int64) * stride.astype(np.int64)) \
            % cfg.vocab
        noise = (h % np.uint64(cfg.vocab)).astype(np.int64)
        is_noise = (h >> np.uint64(5)) % np.uint64(8) == 0
        toks = np.where(is_noise, noise, base).astype(np.int32)
        out["tokens"] = toks
        out["labels"] = toks  # LM: loss shifts internally
    if cfg.cross_attn_every:
        tv, d = cfg.n_vision_tokens, cfg.d_model
        sub = _threefry_like(ctr[:, :1] + np.uint64(7), seed + 2)
        rng = np.random.default_rng(int(sub[0, 0] % np.uint64(2**31)))
        out["vision_embeds"] = rng.standard_normal(
            (rows, tv, d)).astype(np.float32)
    return out


class Prefetcher:
    """Background-thread batch prefetch (depth-bounded)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0, shard: ShardSpec = ShardSpec(),
                 start_step: int = 0, depth: int = 2):
        self.cfg, self.batch, self.seq = cfg, batch, seq_len
        self.seed, self.shard = seed, shard
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._loop, daemon=True)
        self._t.start()

    def _loop(self):
        while not self._stop.is_set():
            try:
                b = synth_batch(self.cfg, self._step, self.batch, self.seq,
                                self.seed, self.shard)
            except Exception as e:  # propagate to the consumer
                self._q.put(e)
                return
            self._q.put((self._step, b))
            self._step += 1

    def next(self):
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

"""Domain-Explorer workload model: user queries -> Travel Solutions -> MCT
queries (paper §2.2, §5.1).

Reproduces the production snapshot statistics the paper reports: 6,301 user
queries -> 5.8M potential TSs -> 4.8M MCT queries; ~17% of TSs are direct
flights (no MCT call); non-direct TSs spawn 1.24 MCT queries on average
(1..5 connections, capped); the engine explores up to 1,500 qualified TSs
per user query.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.rules import RuleSet, generate_queries

MAX_QUALIFIED_TS = 1_500


@dataclass
class TravelSolution:
    n_connections: int            # 0 == direct flight
    mct_queries: List[Dict[str, int]] = field(default_factory=list)


@dataclass
class UserQuery:
    uid: int
    required_ts: int              # qualified TSs requested (batching driver)
    solutions: List[TravelSolution] = field(default_factory=list)

    @property
    def n_mct(self) -> int:
        return sum(len(ts.mct_queries) for ts in self.solutions)


def generate_workload(ruleset: RuleSet, n_user_queries: int, *,
                      seed: int = 0, mean_ts: float = 920.0,
                      direct_frac: float = 0.17,
                      mean_mct_per_ts: float = 1.24) -> List[UserQuery]:
    """Synthetic trace with the production snapshot's shape."""
    rng = np.random.default_rng(seed)
    out: List[UserQuery] = []
    for uid in range(n_user_queries):
        # log-normal TS counts (heavy tail, mean ~ mean_ts)
        n_ts = int(np.clip(rng.lognormal(np.log(mean_ts) - 0.5, 1.0), 1,
                           8_000))
        required = int(rng.choice([200, 500, 1_000, 1_500],
                                  p=[0.25, 0.3, 0.3, 0.15]))
        n_direct = rng.binomial(n_ts, direct_frac)
        n_indirect = n_ts - n_direct
        # connections per indirect TS: geometric-ish over 1..4,
        # tuned to mean_mct_per_ts
        conns = np.clip(rng.geometric(1.0 / mean_mct_per_ts, n_indirect),
                        1, 4)
        total_mct = int(conns.sum())
        mq = generate_queries(ruleset, total_mct, seed=seed * 977 + uid)
        sols = [TravelSolution(0) for _ in range(n_direct)]
        off = 0
        for c in conns:
            sols.append(TravelSolution(int(c), mq[off:off + int(c)]))
            off += int(c)
        rng.shuffle(sols)
        out.append(UserQuery(uid=uid, required_ts=required, solutions=sols))
    return out


def workload_stats(wl: Sequence[UserQuery]) -> Dict[str, float]:
    n_ts = sum(len(u.solutions) for u in wl)
    n_direct = sum(1 for u in wl for t in u.solutions
                   if t.n_connections == 0)
    n_mct = sum(u.n_mct for u in wl)
    return {
        "user_queries": len(wl),
        "travel_solutions": n_ts,
        "mct_queries": n_mct,
        "direct_frac": n_direct / max(n_ts, 1),
        "mct_per_indirect_ts": n_mct / max(n_ts - n_direct, 1),
    }

"""Query encoder — the paper's *Encoder* module (§4.1).

Adapts software data representations (raw ids, code-share fields) to the
dense dictionary-encoded form the accelerator consumes. Cross-matching
criteria (v2 §3.2.3/3.2.4) are resolved HERE: the marketing vs operating
carrier / flight-number is selected by the code-share indicator, so the
kernel stays a generic conjunction engine.

Vectorised (numpy) — in the deployed system this runs on the host,
pipelined with the previous batch's kernel execution.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.compiler import OOV_CODE, CompiledRuleTable
from repro.core.rules import WILDCARD


def queries_to_arrays(queries: Sequence[Dict[str, int]]) -> Dict[str, np.ndarray]:
    """AoS -> SoA: list of query dicts to arrays per field."""
    if not queries:
        return {}
    keys = set()
    for q in queries:
        keys.update(q.keys())
    return {k: np.asarray([q.get(k, 0) for q in queries], np.int64)
            for k in sorted(keys)}


def encode(table: CompiledRuleTable, fields: Dict[str, np.ndarray]
           ) -> np.ndarray:
    """Encode raw query fields into the (B, C) int32 kernel input."""
    n = len(next(iter(fields.values())))
    out = np.zeros((n, table.n_cols), np.int32)
    for j, col in enumerate(table.columns):
        if col.cross_fields is not None:
            # cross-matching (v2): select the query field by the code-share
            # indicator; the kernel stays a generic conjunction engine.
            primary, fallback, cs_f = col.cross_fields
            cs = fields[cs_f].astype(bool)
            raw = np.where(cs, fields[primary], fields[fallback]) \
                .astype(np.int64)
        else:
            src = col.source
            raw = fields[src].astype(np.int64)
        if col.kind == "cat":
            d = table.dictionaries[col.source]
            lut_keys = np.fromiter(d.keys(), np.int64, len(d))
            lut_vals = np.fromiter(d.values(), np.int64, len(d))
            codes = np.full(raw.shape, int(OOV_CODE), np.int64)
            if len(d):
                sort = np.argsort(lut_keys)
                pos = np.searchsorted(lut_keys[sort], raw)
                pos = np.clip(pos, 0, len(d) - 1)
                hit = lut_keys[sort][pos] == raw
                codes = np.where(hit, lut_vals[sort][pos], codes)
            out[:, j] = codes.astype(np.int32)
        else:  # range / range_lo / range_hi: raw numeric value
            out[:, j] = raw.astype(np.int32)
    return out


def encode_queries(table: CompiledRuleTable,
                   queries: Sequence[Dict[str, int]]) -> np.ndarray:
    return encode(table, queries_to_arrays(queries))

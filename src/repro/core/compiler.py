"""Offline rule compiler — the TPU analog of ERBIUM's NFA Optimiser /
Constraint Generator / NFA Parser (Fig. 2 of the paper).

Lowers a RuleSet to a dense interval table executed by the rule-match kernel:

- *Criteria ordering* (NFA Optimiser): columns ordered by estimated
  selectivity; the most selective high-cardinality criterion (airport) is
  chosen as the partition key (the analog of the NFA's first-level fanout).
- *Criteria merging* (v2, §3.2.1): each range criterion expands to two
  columns (value >= lo, value <= hi) — more "NFA steps", exactly like the
  standard's pair-of-values -> two-criteria change.
- *Dynamic range precision weights via overlap elimination* (v2, §3.2.2):
  overlapping flight-number ranges are split offline into disjoint
  sub-rules so the online reduction stays a plain max; weights use the
  ORIGINAL range size.
- *Cross-matching criteria* (v2, §3.2.3/3.2.4): resolved at encode time via
  the schema's cross_fields — the kernel stays a generic conjunction engine.
- *Dictionary building*: categorical raw values -> dense codes (frequency
  sorted); OOV raw values map to a sentinel that only matches wildcards.

The hardware engine never changes across rule-standard versions — all v1/v2
semantics live here, in software. (The paper's central maintainability
lesson.)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.rules import (RANGE_MAX, WILDCARD, Criterion, Rule, RuleSet)

INT_MAX = np.iinfo(np.int32).max - 1
OOV_CODE = np.int32(INT_MAX - 1)


@dataclass
class Column:
    name: str               # criterion name
    source: str             # source criterion
    kind: str               # "cat" | "range_lo" | "range_hi" | "range"
    weight: int
    cross_fields: Optional[Tuple[str, str, str]] = None


@dataclass
class CompiledRuleTable:
    columns: List[Column]
    mins: np.ndarray        # (R, C) int32
    maxs: np.ndarray        # (R, C) int32
    weights: np.ndarray     # (R,) int32
    decisions: np.ndarray   # (R,) int32
    rule_ids: np.ndarray    # (R,) int32 (source rule id, post-splitting)
    dictionaries: Dict[str, Dict[int, int]]
    version: int
    default_decision: int
    # partition table (NFA first-level fanout analog)
    partition_col: int
    n_partitions: int
    part_of_rule: np.ndarray       # (R,) partition id; -1 == wildcard
    part_order: np.ndarray         # (R,) rule indices sorted by partition
    part_offsets: np.ndarray       # (NP+1,)
    wildcard_rows: np.ndarray      # indices of wildcard-partition rules

    @property
    def n_rules(self) -> int:
        return int(self.mins.shape[0])

    @property
    def n_cols(self) -> int:
        return int(self.mins.shape[1])

    def memory_bytes(self) -> int:
        return sum(a.nbytes for a in
                   (self.mins, self.maxs, self.weights, self.decisions,
                    self.rule_ids, self.part_of_rule, self.part_order,
                    self.part_offsets))


def _selectivity(c: Criterion) -> float:
    if c.kind == "cat":
        return 1.0 / max(c.cardinality, 1)
    return 0.05


def _build_columns(schema: Sequence[Criterion], version: int) -> List[Column]:
    crits = sorted(schema, key=_selectivity)  # most selective first
    cols: List[Column] = []
    for c in crits:
        if c.kind == "cat":
            cols.append(Column(c.name, c.name, "cat", c.weight,
                               c.cross_fields))
        elif version >= 2:
            # criteria merging: one range -> two independent criteria
            cols.append(Column(c.name + ".lo", c.name, "range_lo", c.weight,
                               c.cross_fields))
            cols.append(Column(c.name + ".hi", c.name, "range_hi", 0,
                               c.cross_fields))
        else:
            cols.append(Column(c.name, c.name, "range", c.weight,
                               c.cross_fields))
    return cols


def _split_overlaps(ruleset: RuleSet, crit_name: str = "arr_flightno"
                    ) -> List[Rule]:
    """Offline overlap elimination (§3.2.2) on one flight-number criterion.

    Within groups of rules sharing all other bound values, overlapping
    ranges are split at each other's boundaries; atomic sub-ranges covered
    by several rules keep only the most precise one. Weights are computed
    from the ORIGINAL range size (v2 dynamic weight)."""
    if ruleset.version < 2:
        return list(ruleset.rules)
    groups: Dict[tuple, List[Rule]] = {}
    out: List[Rule] = []
    for r in ruleset.rules:
        v = r.values.get(crit_name, WILDCARD)
        if v == WILDCARD:
            out.append(r)
            continue
        key = tuple(sorted((k, vv if not isinstance(vv, tuple) else vv)
                           for k, vv in r.values.items() if k != crit_name))
        groups.setdefault(key, []).append(r)

    n_extra = 0
    for key, rs in groups.items():
        if len(rs) == 1:
            out.extend(rs)
            continue
        # check pairwise overlap
        ivs = [r.values[crit_name] for r in rs]
        bounds = sorted({b for lo, hi in ivs for b in (lo, hi + 1)})
        atoms = list(zip(bounds[:-1], bounds[1:]))
        overlap = any(
            sum(1 for lo, hi in ivs if lo <= a and a2 - 1 <= hi) > 1
            for a, a2 in atoms)
        if not overlap:
            out.extend(rs)
            continue
        # split: each atomic interval keeps the most precise covering rule
        for a_lo, a_hi in atoms:
            cover = [r for r in rs
                     if r.values[crit_name][0] <= a_lo
                     and a_hi - 1 <= r.values[crit_name][1]]
            if not cover:
                continue
            best = max(cover, key=lambda r: r.weight(ruleset.schema, 2))
            nv = dict(best.values)
            nv[crit_name] = (a_lo, a_hi - 1)
            sub = Rule(values=nv, decision=best.decision,
                       rule_id=best.rule_id)
            # keep ORIGINAL-range weight: stash it
            sub._orig_weight = best.weight(ruleset.schema, 2)  # type: ignore
            out.append(sub)
            n_extra += 1
        n_extra -= len(rs)
    return out


def compile_rules(ruleset: RuleSet) -> CompiledRuleTable:
    schema = ruleset.schema
    version = ruleset.version
    cols = _build_columns(schema, version)
    rules = _split_overlaps(ruleset) if version >= 2 else list(ruleset.rules)
    R, C = len(rules), len(cols)

    # dictionaries: frequency-sorted dense codes per cat criterion
    dicts: Dict[str, Dict[int, int]] = {}
    for c in schema:
        if c.kind != "cat":
            continue
        vals = [r.values.get(c.name, WILDCARD) for r in rules]
        uniq, cnt = np.unique([v for v in vals if v != WILDCARD],
                              return_counts=True)
        order = uniq[np.argsort(-cnt)]
        dicts[c.name] = {int(v): i for i, v in enumerate(order)}

    mins = np.zeros((R, C), np.int32)
    maxs = np.full((R, C), INT_MAX, np.int32)
    weights = np.zeros((R,), np.int32)
    decisions = np.zeros((R,), np.int32)
    rule_ids = np.zeros((R,), np.int32)

    for i, r in enumerate(rules):
        w = getattr(r, "_orig_weight", None)
        weights[i] = w if w is not None else r.weight(schema, version)
        decisions[i] = r.decision
        rule_ids[i] = r.rule_id
        for j, col in enumerate(cols):
            v = r.values.get(col.source, WILDCARD)
            if v == WILDCARD:
                continue
            if col.kind == "cat":
                code = dicts[col.source].get(int(v))
                if code is None:
                    code = int(OOV_CODE)
                mins[i, j] = maxs[i, j] = code
            elif col.kind == "range":
                mins[i, j], maxs[i, j] = int(v[0]), int(v[1])
            elif col.kind == "range_lo":
                mins[i, j] = int(v[0])
            else:  # range_hi
                maxs[i, j] = int(v[1])

    # partition table on the most selective high-cardinality cat criterion
    part_col = next(j for j, col in enumerate(cols)
                    if col.source == "airport")
    np_parts = len(dicts["airport"])
    part = np.where(mins[:, part_col] == maxs[:, part_col],
                    mins[:, part_col], -1).astype(np.int32)
    part[part == int(OOV_CODE)] = -1
    order = np.argsort(np.where(part < 0, np_parts, part),
                       kind="stable").astype(np.int32)
    sorted_part = np.where(part[order] < 0, np_parts, part[order])
    offsets = np.searchsorted(sorted_part, np.arange(np_parts + 1)
                              ).astype(np.int32)
    wildcard_rows = order[offsets[np_parts]:].astype(np.int32)

    return CompiledRuleTable(
        columns=cols, mins=mins, maxs=maxs, weights=weights,
        decisions=decisions, rule_ids=rule_ids, dictionaries=dicts,
        version=version, default_decision=ruleset.default_decision,
        partition_col=part_col, n_partitions=np_parts, part_of_rule=part,
        part_order=order, part_offsets=offsets, wildcard_rows=wildcard_rows)

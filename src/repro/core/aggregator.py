"""Batch-formation policy (paper §5): aggregate MCT queries across the
Travel Solutions of a user query so the accelerator sees large batches.

The paper's compromise: batch size is driven by the user query's
required-qualified-TS count — all potential TSs are batched together when
fewer than required, otherwise multiple required-sized batches. We implement
that policy (`paper_policy`) plus two beyond-paper ones:

- ``greedy_all``: one batch with every MCT query of the user query
  (minimises accelerator calls; what the paper notes would be optimal).
- ``deadline``: cross-USER-query continuous batching with an SLA deadline —
  aggregates requests from concurrent user queries until either the target
  batch size or the deadline is hit (the paper's "delay submitting queries
  to batch several requests" discussion, made concrete). This is the same
  policy object the LM serving engine uses for request batching.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.workload import MAX_QUALIFIED_TS, TravelSolution, UserQuery


@dataclass
class Batch:
    uid: int                       # -1 for mixed (cross-user) batches
    queries: List[Dict[str, int]]
    ts_index: List[Tuple[int, int]]  # (uid, ts position) per query


def paper_policy(uq: UserQuery) -> List[Batch]:
    """Batch size == required qualified TS count (paper §5.2)."""
    batches: List[Batch] = []
    cur = Batch(uq.uid, [], [])
    ts_budget = uq.required_ts
    seen_ts = 0
    for ti, ts in enumerate(uq.solutions):
        if ts.n_connections == 0:
            seen_ts += 1
            continue
        if seen_ts >= MAX_QUALIFIED_TS:
            break
        cur.queries.extend(ts.mct_queries)
        cur.ts_index.extend([(uq.uid, ti)] * len(ts.mct_queries))
        seen_ts += 1
        if seen_ts % ts_budget == 0 and cur.queries:
            batches.append(cur)
            cur = Batch(uq.uid, [], [])
    if cur.queries:
        batches.append(cur)
    return batches


def greedy_all(uq: UserQuery) -> List[Batch]:
    b = Batch(uq.uid, [], [])
    for ti, ts in enumerate(uq.solutions[:MAX_QUALIFIED_TS]):
        b.queries.extend(ts.mct_queries)
        b.ts_index.extend([(uq.uid, ti)] * len(ts.mct_queries))
    return [b] if b.queries else []


@dataclass
class DeadlineAggregator:
    """Cross-request continuous batching with an SLA deadline.

    Time is logical (caller-supplied timestamps), so the policy is testable
    deterministically and reusable for LM serving.
    """
    target_batch: int = 4_096
    deadline: float = 0.002        # seconds of queueing allowed
    _q: deque = dataclasses.field(default_factory=deque)
    _oldest: Optional[float] = None

    def add(self, uid: int, queries: Sequence[Dict[str, int]],
            now: float) -> None:
        """Enqueue without polling — callers that must cap batches per
        drain (the async scheduler) add everything first, then poll with
        an explicit limit."""
        for q in queries:
            self._q.append((uid, q))
        if self._oldest is None and queries:
            self._oldest = now

    def offer(self, uid: int, queries: Sequence[Dict[str, int]],
              now: float) -> List[Batch]:
        self.add(uid, queries, now)
        return self.poll(now)

    def poll(self, now: float, limit: Optional[int] = None) -> List[Batch]:
        """Form ready batches. ``limit`` caps how many full batches are
        drained per call — the async scheduler drains one at a time so the
        bounded admission queue (not this aggregator) absorbs overload and
        backpressure can engage."""
        out: List[Batch] = []
        while len(self._q) >= self.target_batch \
                and (limit is None or len(out) < limit):
            out.append(self._drain(self.target_batch))
        if self._q and (limit is None or len(out) < limit) \
                and self._oldest is not None \
                and now - self._oldest >= self.deadline:
            out.append(self._drain(len(self._q)))
        if not self._q:
            self._oldest = None
        elif out:
            self._oldest = now
        return out

    def pending(self) -> int:
        """Queries currently buffered (counted against the scheduler's
        bounded queue depth)."""
        return len(self._q)

    def next_deadline(self) -> Optional[float]:
        """Logical time at which the oldest buffered item must be flushed;
        None when empty (lets pollers sleep instead of busy-ticking)."""
        return None if self._oldest is None else self._oldest + self.deadline

    def evict_oldest(self, now: float
                     ) -> Optional[Tuple[int, Dict[str, int]]]:
        """Drop and return the oldest buffered item (shed-oldest
        backpressure policy); None when empty. The deadline clock restarts
        at ``now`` for the survivors — per-item enqueue times aren't
        tracked, and inheriting the evicted item's age would flush the
        newer remainder as an early undersized batch."""
        if not self._q:
            return None
        item = self._q.popleft()
        self._oldest = now if self._q else None
        return item

    def flush(self) -> List[Batch]:
        return [self._drain(len(self._q))] if self._q else []

    def _drain(self, n: int) -> Batch:
        b = Batch(-1, [], [])
        for _ in range(n):
            uid, q = self._q.popleft()
            b.queries.append(q)
            b.ts_index.append((uid, -1))
        return b


def batch_stats(batches: Iterable[Batch]) -> Dict[str, float]:
    sizes = [len(b.queries) for b in batches]
    if not sizes:
        return {"n_batches": 0, "mean": 0.0, "p50": 0.0, "p90": 0.0}
    return {"n_batches": len(sizes), "mean": float(np.mean(sizes)),
            "p50": float(np.percentile(sizes, 50)),
            "p90": float(np.percentile(sizes, 90)),
            "max": float(np.max(sizes))}

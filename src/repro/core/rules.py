"""MCT rule model: criteria schema (v1/v2), rules, queries, generators.

Mirrors the paper's structure (§2.3, §3.2): rules are conjunctions of
criteria over airports/terminals/regions/carriers/flight-number ranges/time
frames, standardised by IATA. v1 rules are independent predicates with ranges
as a pair-of-values criterion; v2 adds criteria merging (ranges expand to two
criteria), dynamic precision weights for ranges, cross-matching
marketing/operating carriers via the code-share indicator, and code-share
flight-number ranges.

The *actual* rules have 34 raw criteria consolidating to 26 (v2) / 22 (v1);
our synthetic schema reproduces those counts and realistic cardinalities.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

WILDCARD = -1
RANGE_MAX = 2 ** 30


@dataclass(frozen=True)
class Criterion:
    name: str
    kind: str                 # "cat" | "range"
    cardinality: int = 0      # cat: dictionary size
    domain: Tuple[int, int] = (0, 9_999)  # range: value domain
    weight: int = 1           # intrinsic precision weight
    # cross-matching (v2): this criterion's query value is selected between
    # two query fields by the code-share indicator field:
    # (field used when code-share, field used when not, cs_flag field)
    cross_fields: Optional[Tuple[str, str, str]] = None


def schema_v1() -> List[Criterion]:
    """22 consolidated criteria; ranges are native pair-of-values."""
    cats = [
        Criterion("airport", "cat", 500, weight=64),
        Criterion("arr_terminal", "cat", 12, weight=16),
        Criterion("dep_terminal", "cat", 12, weight=16),
        Criterion("arr_region", "cat", 8, weight=8),
        Criterion("dep_region", "cat", 8, weight=8),
        Criterion("arr_country", "cat", 240, weight=24),
        Criterion("dep_country", "cat", 240, weight=24),
        Criterion("arr_carrier", "cat", 900, weight=32),
        Criterion("dep_carrier", "cat", 900, weight=32),
        Criterion("arr_flight_kind", "cat", 4, weight=4),
        Criterion("dep_flight_kind", "cat", 4, weight=4),
        Criterion("arr_aircraft", "cat", 50, weight=8),
        Criterion("dep_aircraft", "cat", 50, weight=8),
        Criterion("prev_airport", "cat", 500, weight=12),
        Criterion("next_airport", "cat", 500, weight=12),
        Criterion("arr_state", "cat", 60, weight=6),
        Criterion("dep_state", "cat", 60, weight=6),
        Criterion("weekday", "cat", 8, weight=4),
        Criterion("season", "cat", 4, weight=4),
    ]
    ranges = [
        Criterion("arr_flightno", "range", domain=(0, 9_999), weight=48),
        Criterion("dep_flightno", "range", domain=(0, 9_999), weight=48),
        Criterion("date", "range", domain=(0, 730), weight=16),
    ]
    return cats + ranges  # 19 + 3 = 22


def schema_v2() -> List[Criterion]:
    """26 consolidated criteria: v1 + code-share carrier/flight-no handling.

    Carrier criteria become cross-matching (marketing vs operating selected
    by the code-share indicator at encode time), and code-share flight-number
    range criteria are added (§3.2.3/3.2.4).
    """
    base = schema_v1()
    out = []
    for c in base:
        if c.name in ("arr_carrier", "dep_carrier"):
            side = c.name.split("_")[0]
            out.append(dataclasses.replace(
                c, name=f"{side}_mkt_carrier",
                cross_fields=(f"{side}_mkt_carrier", f"{side}_mkt_carrier",
                              f"{side}_cs")))
            out.append(dataclasses.replace(
                c, name=f"{side}_op_carrier", weight=28,
                cross_fields=(f"{side}_op_carrier", f"{side}_mkt_carrier",
                              f"{side}_cs")))
        else:
            out.append(c)
    for side in ("arr", "dep"):
        out.append(Criterion(
            f"{side}_cs_flightno", "range", domain=(0, 9_999), weight=40,
            cross_fields=(f"{side}_cs_flightno", f"{side}_flightno",
                          f"{side}_cs")))
    return out  # 22 + 2 + 2 = 26


@dataclass
class Rule:
    """values[name]: cat -> int or WILDCARD; range -> (lo, hi) or WILDCARD."""
    values: Dict[str, object]
    decision: int             # MCT minutes
    rule_id: int = 0

    def weight(self, schema: Sequence[Criterion], version: int = 1) -> int:
        """Precision weight: sum of intrinsic weights of bound criteria;
        v2 adds a dynamic penalty for wide ranges (§3.2.2)."""
        w = 0
        for c in schema:
            v = self.values.get(c.name, WILDCARD)
            if v == WILDCARD:
                continue
            if c.kind == "range":
                lo, hi = v
                w += c.weight
                if version >= 2:
                    size = max(hi - lo, 0) + 1
                    w -= min(int(np.ceil(np.log2(size + 1))), c.weight // 2)
            else:
                w += c.weight
        return w


@dataclass
class RuleSet:
    schema: List[Criterion]
    rules: List[Rule]
    version: int = 1
    default_decision: int = 999


# ---------------------------------------------------------------------------
# Synthetic generators (production-like statistics)
# ---------------------------------------------------------------------------


def _zipf_choice(rng, n, size, a=1.3):
    """Zipf-skewed categorical values in [0, n)."""
    ranks = rng.zipf(a, size=size)
    return np.minimum(ranks - 1, n - 1).astype(np.int64)


def generate_rules(n_rules: int, version: int = 1, seed: int = 0,
                   wildcard_p: float = 0.55, overlap_p: float = 0.002
                   ) -> RuleSet:
    """Synthetic IATA-like rule set. Airlines contribute per-airport rule
    lists; most criteria are wildcards in most rules; flight-number ranges
    overlap rarely (paper: zero to a few hundred overlaps in 160k rules)."""
    rng = np.random.default_rng(seed)
    schema = schema_v2() if version >= 2 else schema_v1()
    by_name = {c.name: c for c in schema}
    rules = []
    airports = _zipf_choice(rng, by_name["airport"].cardinality, n_rules)
    for i in range(n_rules):
        vals: Dict[str, object] = {}
        vals["airport"] = int(airports[i])
        for c in schema:
            if c.name == "airport":
                continue
            if rng.random() < wildcard_p:
                vals[c.name] = WILDCARD
            elif c.kind == "cat":
                vals[c.name] = int(_zipf_choice(rng, c.cardinality, 1)[0])
            else:
                lo = int(rng.integers(c.domain[0], c.domain[1]))
                width = int(rng.integers(1, max((c.domain[1] - lo) // 4, 2)))
                if rng.random() < overlap_p * 50:
                    width = max(width // 8, 1)
                vals[c.name] = (lo, min(lo + width, c.domain[1]))
        decision = int(rng.choice([20, 25, 30, 35, 40, 45, 60, 75, 90, 120]))
        rules.append(Rule(values=vals, decision=decision, rule_id=i))
    return RuleSet(schema=schema, rules=rules, version=version)


def generate_queries(ruleset: RuleSet, n: int, seed: int = 0,
                     match_bias: float = 0.7) -> List[Dict[str, int]]:
    """MCT queries with production-like skew. With prob `match_bias` a query
    is derived from a random rule (guaranteeing matches exist)."""
    rng = np.random.default_rng(seed + 1)
    schema = ruleset.schema
    by_name = {c.name: c for c in schema}
    queries = []
    for _ in range(n):
        q: Dict[str, int] = {}
        base: Optional[Rule] = None
        if rng.random() < match_bias and ruleset.rules:
            base = ruleset.rules[int(rng.integers(len(ruleset.rules)))]
        for c in schema:
            v = base.values.get(c.name, WILDCARD) if base else WILDCARD
            if c.kind == "cat":
                if v == WILDCARD:
                    q[c.name] = int(_zipf_choice(rng, c.cardinality, 1)[0])
                else:
                    q[c.name] = int(v)
            else:
                if v == WILDCARD:
                    q[c.name] = int(rng.integers(c.domain[0], c.domain[1]))
                else:
                    lo, hi = v
                    q[c.name] = int(rng.integers(lo, hi + 1))
        # cross-match raw fields (v2): mkt/op carriers + code-share flags.
        # Values already derived from the base rule are preserved so that
        # encoder-side cross-matching reconstructs the rule's view.
        if ruleset.version >= 2:
            for side in ("arr", "dep"):
                op_n = f"{side}_op_carrier"
                mk_n = f"{side}_mkt_carrier"
                csf_n = f"{side}_cs_flightno"
                bound_op = (base is not None and
                            base.values.get(op_n, WILDCARD) != WILDCARD)
                bound_csf = (base is not None and
                             base.values.get(csf_n, WILDCARD) != WILDCARD)
                cs = 1 if (bound_op or bound_csf) \
                    else int(rng.random() < 0.15)
                q[f"{side}_cs"] = cs
                if not cs:
                    q[op_n] = q[mk_n]  # no code-share: operating == marketing
        queries.append(q)
    return queries

"""ERBIUM engine (online side): Host-Executor + FPGA-kernel analog.

``ErbiumEngine`` owns the device-resident rule table and exposes batched
matching; ``n_engines`` reproduces the paper's 'NFA evaluation engines per
kernel' axis (parallel lanes over a batch), ``n_kernels`` the kernels-per-
accelerator axis (independent engines with their own table replica).

Rule hot-reload (the paper's 500 µs NFA update) swaps the device table
buffers without touching the compiled matcher.

CPU baselines (paper §5.2): ``cpu_match_numpy`` — the optimised vectorised
implementation standing in for the refactored C++ MCT v2 module; and
``cpu_match_python`` — a per-query scalar loop (the pre-optimisation shape).
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compiler import CompiledRuleTable, compile_rules
from repro.core.encoder import encode, queries_to_arrays
from repro.core.rules import RuleSet
from repro.kernels import ops


class ErbiumEngine:
    def __init__(self, table: CompiledRuleTable, *, n_engines: int = 1,
                 tile_b: int = 256, tile_r: int = 512,
                 backend: str = "pallas", partitioned: bool = False,
                 interpret: bool = True):
        self.table = table
        self.n_engines = n_engines
        self.tile_b, self.tile_r = tile_b, tile_r
        self.backend = backend
        self.partitioned = partitioned
        self.interpret = interpret
        self.dt = ops.device_table(table, tile_r=tile_r,
                                   partitioned=partitioned)
        self.reload_us: Optional[float] = None

    # -- online path ---------------------------------------------------------
    def encode(self, fields: Dict[str, np.ndarray]) -> np.ndarray:
        return encode(self.table, fields)

    def match(self, encoded) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """(decision, weight, rule_id), each (B,)."""
        q = jnp.asarray(encoded, jnp.int32)
        if self.partitioned:
            return ops.match_rules_partitioned(q, self.dt)
        return ops.match_rules(q, self.dt, tile_b=self.tile_b,
                               tile_r=self.tile_r, backend=self.backend,
                               n_engines=self.n_engines,
                               interpret=self.interpret)

    def encode_queries_host(self, queries: Sequence[Dict[str, int]]
                            ) -> np.ndarray:
        """Host-side half of the online path: raw query dicts -> dense
        (B, C) int32 kernel input. Pure numpy — the async scheduler runs
        this for batch N+1 while the device executes batch N."""
        return self.encode(queries_to_arrays(list(queries)))

    def match_queries(self, queries: Sequence[Dict[str, int]]):
        return self.match(self.encode_queries_host(queries))

    # -- rule update (hot reload) --------------------------------------------
    def reload(self, ruleset: RuleSet) -> float:
        """Swap in a new rule set; returns device-swap time in µs (the
        analog of the paper's 500 µs NFA reload; compilation is offline)."""
        table = compile_rules(ruleset)
        t0 = time.perf_counter()
        dt = ops.device_table(table, tile_r=self.tile_r,
                              partitioned=self.partitioned)
        jax.block_until_ready(dt.mins_t)
        us = (time.perf_counter() - t0) * 1e6
        self.table, self.dt, self.reload_us = table, dt, us
        return us


# ---------------------------------------------------------------------------
# CPU baselines
# ---------------------------------------------------------------------------


def cpu_match_numpy(table: CompiledRuleTable, encoded: np.ndarray,
                    block: int = 4096):
    """Optimised vectorised CPU implementation (the refactored-C++ stand-in).
    Uses the same partition pruning available to the software module."""
    B = encoded.shape[0]
    dec = np.full((B,), -1, np.int32)
    wgt = np.full((B,), -1, np.int32)
    rid = np.full((B,), -1, np.int32)
    mins, maxs, w = table.mins, table.maxs, table.weights
    for s in range(0, B, block):
        q = encoded[s:s + block]
        ok = (q[:, None, :] >= mins[None]) & (q[:, None, :] <= maxs[None])
        m = ok.all(-1)
        score = np.where(m, w[None, :], -1)
        best = score.max(1)
        idx = score.argmax(1)
        good = best >= 0
        dec[s:s + block] = np.where(good, table.decisions[idx], -1)
        wgt[s:s + block] = best
        rid[s:s + block] = np.where(good, table.rule_ids[idx], -1)
    return dec, wgt, rid


def cpu_match_python(table: CompiledRuleTable, encoded: np.ndarray,
                     limit: Optional[int] = None):
    """Naive per-query scalar loop (pre-optimisation baseline)."""
    B = encoded.shape[0] if limit is None else min(limit, encoded.shape[0])
    mins, maxs, w = table.mins, table.maxs, table.weights
    out = np.full((B, 3), -1, np.int64)
    for i in range(B):
        q = encoded[i]
        best_w, best_r = -1, -1
        for r in range(mins.shape[0]):
            okr = True
            for c in range(mins.shape[1]):
                v = q[c]
                if v < mins[r, c] or v > maxs[r, c]:
                    okr = False
                    break
            if okr and w[r] > best_w:
                best_w, best_r = int(w[r]), r
        if best_r >= 0:
            out[i] = (table.decisions[best_r], best_w,
                      table.rule_ids[best_r])
    return out[:, 0], out[:, 1], out[:, 2]

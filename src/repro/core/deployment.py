"""Deployment analyzer: the paper's parallel-configuration study (§4.3) and
Pareto analysis (Fig. 11), generalised.

A configuration is (p processes, w workers, k kernels, e engines/kernel).
Stage costs are CALIBRATED from real measurements on this machine
(wrapper.measure_stage_times); the multi-element scaling is then evaluated
with a deterministic pipeline model that reproduces the paper's observed
couplings:

- engines/kernel speed up a single request but lower the clock (paper: ~30%
  lower frequency at 4 engines => sub-linear gain)   [Fig 7]
- more kernels raise throughput but slow each request (bigger circuit,
  slower clock)                                       [Fig 8]
- many workers feeding one kernel saturate the XRT-scheduler analog:
  dispatch serialises, latency grows linearly in feeders  [Fig 9]
- several processes per worker saturate the worker at ~16 p/w [Fig 10]

The same analyzer is reused by the LM serving engine to choose mesh/batch
configurations (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.wrapper import StageTimes

# paper-calibrated derating factors
FREQ_DERATE_PER_ENGINE = {1: 1.00, 2: 0.85, 4: 0.70}   # ~30% @ 4 engines
FREQ_DERATE_PER_KERNEL = {1: 1.00, 2: 0.90, 4: 0.80}
WORKER_SATURATION = 16          # processes per worker (Fig 10)
XRT_DISPATCH_US = 35.0          # per-feeder serialisation cost (Fig 9)


@dataclass(frozen=True)
class Config:
    p: int   # producer processes
    w: int   # wrapper workers
    k: int   # kernels
    e: int   # engines per kernel

    def label(self) -> str:
        return f"{self.p}p {self.w}w {self.k}k {self.e}e"


@dataclass
class Perf:
    config: Config
    batch: int
    throughput_qps: float
    latency_us: float           # per-request execution time (90th pct analog)


def _interp_stage(times: Sequence[StageTimes], batch: int):
    """Log-log interpolation of measured stage costs at a batch size."""
    bs = np.array([t.batch for t in times], float)
    out = {}
    for name in ("encode_us", "dispatch_us", "kernel_us", "collect_us"):
        ys = np.array([getattr(t, name) for t in times], float)
        ys = np.maximum(ys, 1e-3)
        out[name] = float(np.exp(np.interp(np.log(batch), np.log(bs),
                                           np.log(ys))))
    return out


def evaluate(cfg: Config, stage_times: Sequence[StageTimes],
             batch: int) -> Perf:
    s = _interp_stage(stage_times, batch)
    e_der = FREQ_DERATE_PER_ENGINE.get(cfg.e, 0.7)
    k_der = FREQ_DERATE_PER_KERNEL.get(cfg.k, 0.8)
    clock = e_der * k_der

    # single-request path: encode on worker, dispatch (serialised per
    # feeding thread at the XRT analog), kernel split over e engines
    feeders = max(cfg.w // cfg.k, 1)
    kernel_us = s["kernel_us"] / (cfg.e * clock)
    dispatch_us = s["dispatch_us"] + XRT_DISPATCH_US * feeders
    # worker saturation: >16 producers per worker stop helping
    eff_p = min(cfg.p, cfg.w * WORKER_SATURATION)
    latency = (s["encode_us"] + dispatch_us + kernel_us + s["collect_us"])

    # pipeline throughput: encode (w workers) overlaps kernel (k kernels)
    enc_stage = s["encode_us"] / cfg.w
    ker_stage = (kernel_us + dispatch_us) / cfg.k
    col_stage = s["collect_us"] / cfg.w
    bottleneck_us = max(enc_stage, ker_stage, col_stage)
    # producers must generate enough load
    prod_rate = eff_p / max(s["encode_us"] * 0.25, 1.0)  # req/us upper bound
    tput = min(batch / bottleneck_us, prod_rate * batch) * 1e6
    return Perf(config=cfg, batch=batch, throughput_qps=tput,
                latency_us=latency)


def sweep(configs: Sequence[Config], stage_times: Sequence[StageTimes],
          batches: Sequence[int]) -> List[Perf]:
    return [evaluate(c, stage_times, b) for c in configs for b in batches]


def pareto(perfs: Sequence[Perf]) -> List[Perf]:
    """Non-dominated (max throughput, min latency) front."""
    pts = sorted(perfs, key=lambda p: (-p.throughput_qps, p.latency_us))
    front, best_lat = [], float("inf")
    for p in pts:
        if p.latency_us < best_lat:
            front.append(p)
            best_lat = p.latency_us
    return front

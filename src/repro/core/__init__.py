"""Core: the paper's contribution — ERBIUM-on-TPU rule engine + the
deployment/integration analysis layer (wrapper, aggregator, workload model,
parallel-config analyzer, cost model)."""
from repro.core.compiler import CompiledRuleTable, compile_rules  # noqa
from repro.core.encoder import encode_queries  # noqa
from repro.core.engine import ErbiumEngine  # noqa
from repro.core.rules import RuleSet, generate_queries, generate_rules  # noqa

"""MCT Wrapper — the paper's multi-threaded Host-Executor (§4.1).

Round-robin dealer over worker threads; each worker encodes its batch
(pipelined with the previous batch's kernel execution), dispatches to an
engine lane, and collects/partitions results back per Travel Solution.
Every stage is timed (paper Fig. 6 decomposition):

  queue -> encode -> dispatch (host->device) -> kernel -> collect

On this CPU-only container the host<->device hop is process-internal; the
stage structure and relative scaling with batch size reproduce the paper's
phenomena (transfer/encode dominance at small/large batches respectively),
and the measured stage costs calibrate the deployment simulator (Figs 7-11).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregator import Batch
from repro.core.encoder import queries_to_arrays
from repro.core.engine import ErbiumEngine


@dataclass
class StageTimes:
    queue_us: float = 0.0
    encode_us: float = 0.0
    dispatch_us: float = 0.0
    kernel_us: float = 0.0
    collect_us: float = 0.0
    batch: int = 0

    @property
    def total_us(self) -> float:
        return (self.queue_us + self.encode_us + self.dispatch_us +
                self.kernel_us + self.collect_us)


@dataclass
class MCTResult:
    uid: int
    decisions: np.ndarray
    weights: np.ndarray
    times: StageTimes


class MCTWrapper:
    """n_workers worker threads sharing one engine pool (1..k engines)."""

    def __init__(self, engines: Sequence[ErbiumEngine], n_workers: int = 1):
        self.engines = list(engines)
        self.n_workers = n_workers
        self._in: "queue.Queue" = queue.Queue()
        self._out: "queue.Queue" = queue.Queue()
        self._threads: List[threading.Thread] = []
        self._stop = threading.Event()
        self._rr = 0

    # -- lifecycle -----------------------------------------------------------
    def start(self):
        for wi in range(self.n_workers):
            t = threading.Thread(target=self._worker_loop,
                                 args=(wi,), daemon=True)
            t.start()
            self._threads.append(t)

    def stop(self):
        self._stop.set()
        for _ in self._threads:
            self._in.put(None)
        for t in self._threads:
            t.join(timeout=5)
        self._threads.clear()
        self._stop.clear()

    # -- request path --------------------------------------------------------
    def submit(self, batch: Batch):
        self._in.put((time.perf_counter(), batch))

    def drain(self, n: int, timeout: float = 60.0) -> List[MCTResult]:
        out = []
        for _ in range(n):
            out.append(self._out.get(timeout=timeout))
        return out

    def process(self, batch: Batch, engine_idx: int = 0) -> MCTResult:
        """Synchronous single-request path (used for stage benchmarking)."""
        return self._execute(time.perf_counter(), batch, engine_idx)

    # -- internals ------------------------------------------------------------
    def _worker_loop(self, wi: int):
        while not self._stop.is_set():
            item = self._in.get()
            if item is None:
                return
            t_in, batch = item
            eng = wi % len(self.engines)
            self._out.put(self._execute(t_in, batch, eng))

    def _execute(self, t_in: float, batch: Batch, eng_idx: int) -> MCTResult:
        st = StageTimes(batch=len(batch.queries))
        eng = self.engines[eng_idx]
        t0 = time.perf_counter()
        st.queue_us = (t0 - t_in) * 1e6

        fields = queries_to_arrays(batch.queries)
        enc = eng.encode(fields)
        t1 = time.perf_counter()
        st.encode_us = (t1 - t0) * 1e6

        dev = jax.device_put(jnp.asarray(enc, jnp.int32))
        dev.block_until_ready()
        t2 = time.perf_counter()
        st.dispatch_us = (t2 - t1) * 1e6

        dec, w, rid = eng.match(dev)
        jax.block_until_ready((dec, w, rid))
        t3 = time.perf_counter()
        st.kernel_us = (t3 - t2) * 1e6

        dec_h = np.asarray(dec)
        w_h = np.asarray(w)
        # partition results back to TSs (collect)
        _ = dec_h.sum()
        t4 = time.perf_counter()
        st.collect_us = (t4 - t3) * 1e6
        return MCTResult(uid=batch.uid, decisions=dec_h, weights=w_h,
                         times=st)


def measure_stage_times(engine: ErbiumEngine, make_batch, batch_sizes,
                        repeats: int = 3) -> List[StageTimes]:
    """Fig-6 style stage decomposition over batch sizes (median of repeats).
    ``make_batch(n)`` returns a Batch with n queries."""
    wrap = MCTWrapper([engine], n_workers=1)
    out = []
    for n in batch_sizes:
        b = make_batch(n)
        wrap.process(b)  # warmup (jit compile)
        runs = [wrap.process(b).times for _ in range(repeats)]
        med = StageTimes(
            batch=n,
            queue_us=float(np.median([r.queue_us for r in runs])),
            encode_us=float(np.median([r.encode_us for r in runs])),
            dispatch_us=float(np.median([r.dispatch_us for r in runs])),
            kernel_us=float(np.median([r.kernel_us for r in runs])),
            collect_us=float(np.median([r.collect_us for r in runs])))
        out.append(med)
    return out

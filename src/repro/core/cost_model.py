"""Deployment cost model (paper §6, Tables 2 and 3) + TPU re-parameterisation.

Reproduces the paper's numbers exactly from its stated unit prices, then
generalises the same balance analysis to TPU v5e serving: the central
phenomenon is CPU<->accelerator imbalance — a host that cannot generate
enough load wastes the accelerator and can make the accelerated system MORE
expensive than CPU-only.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List

HOURS_PER_YEAR = 24 * 365

# paper Table 2 cloud unit prices ($/hour), named so the serving-layer
# cost report prices measured throughput through the same numbers
AWS_C5_12XLARGE_USD_H = 1.452     # 48 vCPUs, CPU-only baseline
AWS_F1_2XLARGE_USD_H = 1.2266     # 8 vCPUs + 1 FPGA
AZURE_F48SV2_USD_H = 1.2084       # 48 vCPUs
AZURE_NP10S_USD_H = 1.0411        # 10 vCPUs + 1 FPGA


def aws_host_usd_per_hour(vcpus: int) -> float:
    """Host-only $/hour for a ``vcpus``-core box, pro-rated from the
    c5.12xlarge (48 vCPUs) — the paper's CPU price anchor."""
    return AWS_C5_12XLARGE_USD_H * (vcpus / 48.0)


def aws_accel_usd_per_hour() -> float:
    """Accelerator-only $/hour: the f1.2xlarge price minus its 8-vCPU
    host share — what one attached FPGA costs on top of whatever host
    feeds it."""
    return AWS_F1_2XLARGE_USD_H - aws_host_usd_per_hour(8)


def usd_per_hour(host_usd_h: float, accel_usd_h: float,
                 replicas: float) -> float:
    """$/hour of one host feeding ``replicas`` accelerators (fractional
    replicas = time-weighted mean of an adaptive active set)."""
    return host_usd_h + replicas * accel_usd_h


def usd_per_1k_queries(usd_h: float, qps: float) -> float:
    """Measured steady-state throughput -> cost per 1000 queries (the
    paper's Tables 2–3 comparison, per measured configuration)."""
    if qps <= 0:
        return float("inf")
    return usd_h / (qps * 3.6)        # qps * 3600 queries/h / 1000


@dataclass(frozen=True)
class Deployment:
    name: str
    element: str
    units: int
    unit_cost_usd: float          # purchase (on-prem) or $/h (cloud)
    cloud: bool = False
    vcpus: int = 0

    @property
    def total_usd(self) -> float:
        if self.cloud:
            return self.units * self.unit_cost_usd * HOURS_PER_YEAR
        return self.units * self.unit_cost_usd


# ---------------------------------------------------------------------------
# Paper Table 2: Domain Explorer + MCT
# ---------------------------------------------------------------------------

# constants from the paper
_SERVERS = 400                    # CPU-only servers needed for current load
_MCT_CPU_SHARE = 0.40             # MCT share of Domain-Explorer compute
_FPGA_SERVERS = 244               # 400 * (1 - 0.40) rounded up by the paper
_AWS_RATIO = 48 / 8               # c5.12xlarge vCPUs / f1.2xlarge vCPUs
_AZ_RATIO = 48 / 10


def table2() -> List[Deployment]:
    return [
        Deployment("On-Premises / Original Domain Explorer", "CPU",
                   _SERVERS, 10_000, vcpus=48),
        Deployment("On-Premises / DE + ERBIUM (Alveo U200)",
                   "CPU + Alveo U200", _FPGA_SERVERS, 20_000, vcpus=48),
        Deployment("On-Premises / DE + ERBIUM (Alveo U50)",
                   "CPU + Alveo U50", _FPGA_SERVERS, 13_000, vcpus=48),
        Deployment("AWS / Original Domain Explorer", "c5.12xlarge",
                   _SERVERS, AWS_C5_12XLARGE_USD_H, cloud=True, vcpus=48),
        Deployment("AWS / DE + ERBIUM", "f1.2xlarge",
                   int(_FPGA_SERVERS * _AWS_RATIO), AWS_F1_2XLARGE_USD_H, cloud=True,
                   vcpus=8),
        Deployment("Azure / Original Domain Explorer", "F48s v2",
                   _SERVERS, AZURE_F48SV2_USD_H, cloud=True, vcpus=48),
        Deployment("Azure / DE + ERBIUM", "NP10s",
                   int(round(_FPGA_SERVERS * _AZ_RATIO)), AZURE_NP10S_USD_H, cloud=True,
                   vcpus=10),
    ]


def table3() -> List[Deployment]:
    """Table 3: + Route Scoring (80 extra CPU servers on the baseline;
    the FPGA deployment absorbs Route Scoring on the same boards)."""
    return [
        Deployment("On-Premises / Original DE + Route Scoring", "CPU",
                   _SERVERS + 80, 10_000, vcpus=48),
        Deployment("On-Premises / DE + ERBIUM + RS (U200)",
                   "CPU + Alveo U200", _FPGA_SERVERS, 20_000, vcpus=48),
        Deployment("On-Premises / DE + ERBIUM + RS (U50)",
                   "CPU + Alveo U50", _FPGA_SERVERS, 13_000, vcpus=48),
        Deployment("AWS / Original DE + Route Scoring", "c5.12xlarge",
                   _SERVERS + 80, AWS_C5_12XLARGE_USD_H, cloud=True, vcpus=48),
        Deployment("AWS / DE + ERBIUM + RS", "f1.2xlarge",
                   int(_FPGA_SERVERS * _AWS_RATIO), AWS_F1_2XLARGE_USD_H, cloud=True,
                   vcpus=8),
        Deployment("Azure / Original DE + Route Scoring", "F48s v2",
                   _SERVERS + 80, AZURE_F48SV2_USD_H, cloud=True, vcpus=48),
        Deployment("Azure / DE + ERBIUM + RS", "NP10s",
                   int(round(_FPGA_SERVERS * _AZ_RATIO)), AZURE_NP10S_USD_H, cloud=True,
                   vcpus=10),
    ]


# paper-reported totals for validation (USD; cloud = per year)
PAPER_TABLE2_TOTALS = {
    "On-Premises / Original Domain Explorer": 4.0e6,
    "On-Premises / DE + ERBIUM (Alveo U200)": 4.88e6,
    "On-Premises / DE + ERBIUM (Alveo U50)": 3.17e6,
    "AWS / Original Domain Explorer": 5.0e6,
    "AWS / DE + ERBIUM": 15.7e6,
    "Azure / Original Domain Explorer": 4.2e6,
    "Azure / DE + ERBIUM": 10.6e6,
}


# ---------------------------------------------------------------------------
# TPU v5e re-parameterisation (the same imbalance analysis on our target)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TPUCostParams:
    v5e_usd_per_chip_hour: float = 1.2      # on-demand list-ish price
    host_vcpus_per_8chips: int = 112         # v5e host: 2x 56-vCPU hosts/tray
    cpu_only_usd_per_48vcpu_hour: float = 1.452
    # host-side query-generation capacity (queries/s per vCPU), calibrated
    # from the measured encode stage
    host_qps_per_vcpu: float = 250_000.0
    # accelerator capacity (queries/s per chip) from the rule-engine roofline
    accel_qps_per_chip: float = 40_000_000.0


def tpu_balance(params: TPUCostParams, target_qps: float) -> Dict[str, float]:
    """How many chips vs how many vCPUs the workload actually needs, and the
    utilisation the platform's fixed CPU:chip ratio forces."""
    chips_needed = target_qps / params.accel_qps_per_chip
    vcpus_needed = target_qps / params.host_qps_per_vcpu
    # platform couples vcpus to chips:
    vcpus_per_chip = params.host_vcpus_per_8chips / 8
    chips_bought = max(chips_needed, vcpus_needed / vcpus_per_chip)
    util = chips_needed / chips_bought
    cost_acc = chips_bought * params.v5e_usd_per_chip_hour * HOURS_PER_YEAR
    cpu_nodes = vcpus_needed / 48
    cost_cpu_only = (target_qps / (params.host_qps_per_vcpu * 48 * 0.6)
                     ) * params.cpu_only_usd_per_48vcpu_hour * HOURS_PER_YEAR
    return {
        "chips_needed": chips_needed,
        "vcpus_needed": vcpus_needed,
        "chips_bought": chips_bought,
        "accel_utilisation": util,
        "accel_cost_usd_year": cost_acc,
        "cpu_only_cost_usd_year": cost_cpu_only,
        "cost_ratio_accel_vs_cpu": cost_acc / max(cost_cpu_only, 1e-9),
    }

"""Top-level re-exports of the capacity subsystem.

``repro.capacity`` is the public face of
:mod:`repro.serve.capacity` — online bottleneck detection
(:class:`BottleneckMonitor`), the adaptive host/device balance control
loop (:class:`CapacityController`), and cost-efficiency reporting
(:class:`CostReport`, $/1k-queries through the paper's deployment
prices). See that module's docstring for the full story; enable in a
serving stack with ``ServeConfig(capacity=CapacityConfig(...))``.
"""
from repro.serve.capacity import (PAPER_BOXES, Bottleneck,
                                  BottleneckMonitor, BoxPrice,
                                  CapacityConfig, CapacityController,
                                  CapacitySignals, ControllerAction,
                                  CostReport, CostRow)
from repro.serve.metrics import SignalSnapshot

__all__ = [
    "PAPER_BOXES", "Bottleneck", "BottleneckMonitor", "BoxPrice",
    "CapacityConfig", "CapacityController", "CapacitySignals",
    "ControllerAction", "CostReport", "CostRow", "SignalSnapshot",
]

"""Fault tolerance: failure detection, elastic remesh, straggler mitigation.

On a real multi-pod deployment the supervisor runs per-host; here the same
logic is executed in-process with simulated node events, and every piece is
unit-tested (tests/test_ft.py):

- `HeartbeatMonitor`: hosts report heartbeats; silence past `timeout` marks
  the host failed (the detection layer under both elastic restart and
  straggler handling).
- `ElasticPlan`: given surviving device count, pick the largest valid
  (data, model) mesh <= survivors (model parallelism is preserved — losing
  data-parallel replicas only shrinks global batch), rebuild shardings, and
  restore optimizer state from the last checkpoint via
  checkpoint.store.restore(..., shardings=new).
- `StragglerPolicy`: per-step deadline derived from a running latency EWMA;
  microbatch grads that miss the deadline are dropped and the gradient is
  rescaled by contributing/total (backup-worker dispatch is the serving-side
  analog, implemented in serve.engine).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class HeartbeatMonitor:
    timeout: float = 10.0
    _last: Dict[str, float] = field(default_factory=dict)

    def beat(self, host: str, now: Optional[float] = None):
        self._last[host] = time.monotonic() if now is None else now

    def failed(self, now: Optional[float] = None) -> List[str]:
        t = time.monotonic() if now is None else now
        return [h for h, ts in self._last.items() if t - ts > self.timeout]

    def alive(self, now: Optional[float] = None) -> List[str]:
        t = time.monotonic() if now is None else now
        return [h for h, ts in self._last.items() if t - ts <= self.timeout]


@dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int
    global_batch: int
    dropped_devices: int

    @property
    def n_devices(self) -> int:
        return self.data * self.model


def plan_elastic_mesh(n_surviving: int, model_parallel: int,
                      global_batch: int, min_data: int = 1,
                      orig_data: Optional[int] = None) -> ElasticPlan:
    """Largest (data, model) mesh with the SAME model parallelism that fits
    the survivors; global batch shrinks to keep per-replica batch constant.

    Model-parallel groups are atomic: losing one chip kills its whole TP
    group, so survivors round down to a multiple of `model_parallel`.
    """
    if n_surviving < model_parallel * min_data:
        raise ValueError(
            f"not enough devices: {n_surviving} < {model_parallel * min_data}")
    data = n_surviving // model_parallel
    # keep per-replica batch constant; shrink global batch proportionally
    per_replica = max(global_batch // max(orig_data or data, 1), 1)
    gb = per_replica * data
    used = data * model_parallel
    return ElasticPlan(data=data, model=model_parallel, global_batch=gb,
                       dropped_devices=n_surviving - used)


@dataclass
class StragglerPolicy:
    """Deadline = ewma * tolerance. Contributions missing the deadline are
    dropped; the aggregated gradient is rescaled by n_done/n_total."""
    tolerance: float = 3.0
    ewma_alpha: float = 0.2
    _ewma: Optional[float] = None

    def deadline(self) -> Optional[float]:
        return None if self._ewma is None else self._ewma * self.tolerance

    def observe(self, latency: float):
        self._ewma = latency if self._ewma is None else (
            self.ewma_alpha * latency + (1 - self.ewma_alpha) * self._ewma)

    def commit(self, latencies: Sequence[float]
               ) -> Tuple[List[int], float]:
        """Given per-worker step latencies, return (kept worker indices,
        gradient rescale factor)."""
        dl = self.deadline()
        if dl is None:
            kept = list(range(len(latencies)))
        else:
            kept = [i for i, l in enumerate(latencies) if l <= dl]
            if not kept:           # everyone late: keep fastest, reset ewma
                kept = [int(min(range(len(latencies)),
                                key=lambda i: latencies[i]))]
        for i in kept:
            self.observe(latencies[i])
        scale = len(latencies) / max(len(kept), 1)
        return kept, scale


@dataclass
class FailureInjector:
    """Deterministic failure schedule for tests/examples: step -> host."""
    schedule: Dict[int, str] = field(default_factory=dict)

    def check(self, step: int) -> Optional[str]:
        return self.schedule.get(step)


@dataclass
class DelayInjector:
    """Deterministic injected execution delays, keyed by target (host id,
    serving-replica index, ...): the straggler-side sibling of
    ``FailureInjector``. ``repro.serve`` replica workers call
    :meth:`apply` before each device execution, so a delayed replica
    behaves exactly like a slow accelerator — routing and admission-queue
    behaviour under stragglers become testable without real slow hardware.
    """
    delays: Dict[object, float] = field(default_factory=dict)

    def delay_for(self, target) -> float:
        return float(self.delays.get(target, 0.0))

    def apply(self, target, sleep=time.sleep) -> float:
        d = self.delay_for(target)
        if d > 0:
            sleep(d)
        return d

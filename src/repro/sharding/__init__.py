from repro.sharding.specs import ShardCtx, param_specs  # noqa: F401

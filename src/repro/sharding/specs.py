"""Sharding policy: mesh-axis assignment for parameters, activations, caches.

Policy (MaxText-style hybrid):
- ``pod``   — pure data parallelism across pods (DCN): batch only.
- ``data``  — within-pod data parallelism + FSDP (ZeRO-3): batch AND the
  d_model dim of every weight matrix.
- ``model`` — tensor parallelism: attention heads / d_ff / experts / vocab.

Dims that do not divide the axis size are left unsharded (the policy is
divisibility-aware; e.g. hymba's 25 heads stay replicated over ``model``
while its d_ff=5504 is sharded 16-way). Long-context decode cells shard the
KV-cache *sequence* dim instead (set ``cache_seq_axes``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh
    batch_axes: Tuple[str, ...]           # ('data',) or ('pod','data')
    fsdp_axis: Optional[str] = "data"
    model_axis: str = "model"
    # decode-cache sequence sharding (e.g. ('model',) or ('data','model'))
    cache_seq_axes: Optional[Tuple[str, ...]] = None
    # decode-optimised MoE: never gather expert weights (see models/moe.py)
    moe_weight_stationary: bool = False
    # q-block-parallel attention when heads don't divide the model axis
    attn_qblock: bool = False
    # sLSTM: accumulate recurrent-weight grads locally, one trailing psum
    slstm_local_grad: bool = False

    # -- helpers ------------------------------------------------------------
    def _axsz(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        return int(np.prod([self.mesh.shape[a] for a in axes]))

    def div(self, n: int, axes) -> bool:
        s = self._axsz(axes)
        return s > 0 and n % s == 0

    def maybe(self, n: int, axes):
        """axes if n divides evenly over them, else None."""
        return axes if self.div(n, axes) else None

    def _c(self, x, spec):
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh,
                                                                 spec))

    def attn_layout(self, n_heads: int, n_kv: int) -> str:
        """'grouped' when KV heads shard evenly; 'expand' (KV replication up
        to n_heads) when only Q heads do; else 'qblock' (query-block
        sharding) when enabled, or 'grouped' (replicated attention,
        documented imbalance)."""
        if self.model_axis is None:
            return "grouped"
        if self.div(n_kv, self.model_axis):
            return "grouped"
        if self.div(n_heads, self.model_axis):
            return "expand"
        return "qblock" if self.attn_qblock else "grouped"

    def act_qblocks(self, x):
        """(B, nb, Bq, K, G, d): shard the query-block dim over model."""
        b = self.maybe(x.shape[0], self.batch_axes)
        n = self.maybe(x.shape[1], self.model_axis)
        return self._c(x, P(b, n, None, None, None, None))

    # -- activation constraints ----------------------------------------------
    def act_btd(self, x):
        b = self.maybe(x.shape[0], self.batch_axes)
        return self._c(x, P(b, None, None))

    def act_ff(self, x):
        b = self.maybe(x.shape[0], self.batch_axes)
        f = self.maybe(x.shape[-1], self.model_axis)
        return self._c(x, P(b, None, f))

    def act_logits(self, x):
        b = self.maybe(x.shape[0], self.batch_axes)
        v = self.maybe(x.shape[-1], self.model_axis)
        return self._c(x, P(b, None, v))

    def act_kv(self, x):
        """(B, S, K, hd) KV tensors / caches, or grouped q (B,S,K,G,hd)."""
        b = self.maybe(x.shape[0], self.batch_axes)
        if x.ndim == 5:  # grouped q (B, S, K, G, hd): shard K if divisible
            kk = self.maybe(x.shape[2], self.model_axis)
            return self._c(x, P(b, None, kk, None, None))
        kk = self.maybe(x.shape[2], self.model_axis)
        if kk is None and self.cache_seq_axes is not None \
                and self.div(x.shape[1], self.cache_seq_axes):
            return self._c(x, P(b, self.cache_seq_axes, None, None))
        return self._c(x, P(b, None, kk, None))

    def batch_spec(self, batch_shape_tree):
        """Input-batch shardings (tokens/labels/embeds)."""
        def one(sds):
            b = self.maybe(sds.shape[0], self.batch_axes)
            return NamedSharding(self.mesh,
                                 P(*([b] + [None] * (len(sds.shape) - 1))))
        return jax.tree_util.tree_map(one, batch_shape_tree)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

_IN_OUT = {  # name -> (in-dim index from the right, shard out dim on model?)
    "wq": True, "wk": True, "wv": True, "wi": True, "wg": True, "w_in": True,
    "w": True, "wog": True, "wo": False, "w_out": False,
}


def _leaf_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               cfg: ModelConfig, ctx: ShardCtx) -> P:
    """PartitionSpec for one parameter leaf, prefixing stack dims with None."""
    name = path[-1]
    fs, mx = ctx.fsdp_axis, ctx.model_axis
    moe = "moe" in path
    nd = len(shape)

    def pad(spec_tail):
        return P(*([None] * (nd - len(spec_tail)) + list(spec_tail)))

    if name in ("embed", "unembed"):
        v = ctx.maybe(shape[0], mx)
        d = ctx.maybe(shape[1], fs) if fs else None
        return P(v, d)
    if name == "router":
        return pad([ctx.maybe(shape[-2], fs), None])
    if moe and name in ("wi", "wg"):
        if cfg.moe.parallel_mode == "ep" and \
                ctx.div(cfg.moe.num_experts, mx):
            return pad([mx, ctx.maybe(shape[-2], fs), None])
        return pad([None, ctx.maybe(shape[-2], fs),
                    ctx.maybe(shape[-1], mx)])
    if moe and name == "wo":
        if cfg.moe.parallel_mode == "ep" and \
                ctx.div(cfg.moe.num_experts, mx):
            return pad([mx, None, ctx.maybe(shape[-1], fs)])
        return pad([None, ctx.maybe(shape[-2], mx),
                    ctx.maybe(shape[-1], fs)])
    if nd >= 2 and name in _IN_OUT:
        if _IN_OUT[name]:   # (..., D_in, D_out): FSDP in, TP out
            return pad([ctx.maybe(shape[-2], fs), ctx.maybe(shape[-1], mx)])
        return pad([ctx.maybe(shape[-2], mx), ctx.maybe(shape[-1], fs)])
    if name == "a_log":
        return pad([ctx.maybe(shape[-2], mx), None])
    if name == "conv_w":
        return pad([None, ctx.maybe(shape[-1], mx)])
    if name == "r":      # slstm recurrent (4, H, dh, dh)
        return pad([None, None, None])
    # norms, biases, gates, scalars
    return P(*([None] * nd))


def param_specs(params_tree, cfg: ModelConfig, ctx: ShardCtx):
    """PartitionSpec pytree matching a params (or ShapeDtypeStruct) pytree."""
    def walk(path, leaf):
        names = tuple(getattr(k, "key", getattr(k, "idx", "")) for k in path)
        names = tuple(str(n) for n in names)
        return _leaf_spec(names, leaf.shape, cfg, ctx)

    return jax.tree_util.tree_map_with_path(walk, params_tree)


def param_shardings(params_tree, cfg: ModelConfig, ctx: ShardCtx):
    specs = param_specs(params_tree, cfg, ctx)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(ctx.mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def replica_device_groups(mesh: Mesh, axis: str = "data"):
    """Split a mesh's device grid into per-replica device groups along one
    named axis: replica ``i`` gets the (flattened) devices of slice ``i``.

    Serving maps one engine replica per slice
    (``repro.serve.EngineGroup.from_mesh``); the remaining axes stay
    available for intra-replica parallelism, and a replica whose slice
    holds several devices round-robins batches within it.
    """
    if axis not in mesh.axis_names:
        raise ValueError(
            f"mesh has no axis {axis!r} (axes: {tuple(mesh.axis_names)})")
    ax = tuple(mesh.axis_names).index(axis)
    grid = np.moveaxis(np.asarray(mesh.devices), ax, 0)
    return [list(grid[i].ravel()) for i in range(grid.shape[0])]


def cache_shardings(cache_tree, cfg: ModelConfig, ctx: ShardCtx):
    """Shardings for the decode cache tree."""
    mx = ctx.model_axis

    def one(sds):
        shp = sds.shape
        nd = len(shp)
        # attention caches: (..., B, S, K, hd)
        if nd >= 4 and shp[-1] == cfg.head_dim and shp[-2] == cfg.n_kv_heads:
            b = ctx.maybe(shp[-4], ctx.batch_axes)
            k = ctx.maybe(cfg.n_kv_heads, mx)
            s = None
            if k is None and ctx.cache_seq_axes is not None and \
                    ctx.div(shp[-3], ctx.cache_seq_axes):
                s = ctx.cache_seq_axes
            return NamedSharding(
                ctx.mesh, P(*([None] * (nd - 4) + [b, s, k, None])))
        # ssm / xlstm states: shard the widest trailing dim if divisible
        tail = ctx.maybe(shp[-1], mx) if shp[-1] >= 128 else None
        spec = [None] * nd
        spec[-1] = tail
        # batch dim heuristics: first dim after stack dims with |dim|>=dp
        return NamedSharding(ctx.mesh, P(*spec))

    return jax.tree_util.tree_map(one, cache_tree)

"""Paper Fig. 12: per-user-query execution time, optimised CPU baseline vs
the accelerated engine, as a function of MCT queries checked; plus the
number of accelerator calls under the paper's batching policy.

Reproduced phenomenon: CPU wins below a crossover workload (paper: ~400
queries); the engine wins above it even when called multiple times.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, rule_system
from repro.core.aggregator import paper_policy
from repro.core.encoder import encode_queries
from repro.core.engine import ErbiumEngine, cpu_match_numpy
from repro.core.workload import generate_workload
from repro.kernels import ops


def run():
    rs, table, qs, enc = rule_system(2)
    # accelerated path = the partition-pruned engine (the NFA-fanout
    # advantage ERBIUM gets in hardware, here measured for real); the CPU
    # baseline is the optimised vectorised full scan. Interpret-mode Pallas
    # is a correctness harness, not a timing proxy (see README).
    eng = ErbiumEngine(table, partitioned=True)
    wl = generate_workload(rs, 10, seed=7, mean_ts=400.0)
    # warmup compile
    eng.match(enc[:256])

    rows = []
    for uq in sorted(wl, key=lambda u: u.n_mct):
        batches = paper_policy(uq)
        if not batches:
            continue
        encs = [encode_queries(table, b.queries) for b in batches]
        for e in encs:  # warm the jit caches per shape
            jax.block_until_ready(eng.match(jnp.asarray(e, jnp.int32)))
        t0 = time.perf_counter()
        for e in encs:
            cpu_match_numpy(table, e)
        t_cpu = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        for e in encs:
            jax.block_until_ready(eng.match(jnp.asarray(e, jnp.int32)))
        t_acc = (time.perf_counter() - t0) * 1e6
        n = uq.n_mct
        rows.append((n, t_cpu, t_acc, len(batches)))
        emit(f"fig12/uq_mct{n}", t_acc,
             f"cpu_us={t_cpu:.0f};accel_calls={len(batches)};"
             f"speedup={t_cpu / max(t_acc, 1):.2f}")
    big = [r for r in rows if r[0] >= 400]
    if big:
        sp = np.mean([r[1] / r[2] for r in big])
        emit("fig12/speedup_above_400q", 0.0,
             f"mean={sp:.2f} (paper: accel wins above ~400 queries)")
    return rows


if __name__ == "__main__":
    run()

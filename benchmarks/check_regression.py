"""Bench-regression gate: compare a fresh BENCH_endtoend.json against the
committed baseline and fail CI when a throughput section regressed.

Only the *simulation-clock* sections are compared — replica scaling, cache
hit-rate, capacity control, and routing-policy sweeps are dominated by
``SimServer`` sleeps, so their qps is stable across CI machines. The
open-loop load points (``fig13_load_*``), the pipeline-overlap inset, and
raw ``us_per_call`` timings are machine-dependent and deliberately
skipped.

A section regresses when its fresh throughput drops below
``(1 - tolerance)`` of the baseline (default tolerance 15%). A baseline
metric *missing* from the fresh run also fails — a sweep that silently
stopped running is a regression of the harness, not an improvement.
Metrics new in the fresh run (not yet in the baseline) pass with a note,
so sections can be added without a chicken-and-egg dance.

Run:  python benchmarks/check_regression.py \
          --baseline BENCH_baseline.json --fresh BENCH_endtoend.json
"""
import argparse
import json
import sys

# throughput metrics (higher is better), keyed "section[point].metric"
_SKIPPED_PREFIXES = ("fig13_load_", "fig13_pipeline_overlap",
                     "fig14_", "fig13_cache_", "fig13_routing_")


def collect_metrics(payload: dict) -> dict:
    """Flatten a BENCH_endtoend.json payload into comparable qps metrics.

    Returns ``{"section[point].metric": float}`` for every simulation-
    clock throughput number the payload carries.
    """
    out = {}
    for r in payload.get("results", []):
        name = r.get("name", "")
        if any(name.startswith(p) for p in _SKIPPED_PREFIXES):
            continue
        if "achieved_qps" in r:     # fig13_replicas_{r}
            out[f"replicas[{name}].achieved_qps"] = float(r["achieved_qps"])
    for p in payload.get("cache", []):
        key = f"cache[alpha={p['repeat_alpha']:g}," \
              f"{'on' if p['cached'] else 'off'}]"
        out[f"{key}.effective_qps"] = float(p["effective_qps"])
    for p in payload.get("routing", []):
        key = f"routing[{p['scenario']}/{p['policy']}]"
        out[f"{key}.effective_qps"] = float(p["effective_qps"])
    for p in payload.get("capacity", []):
        if not p.get("profile"):    # cost-report entry, not a sweep point
            continue
        key = f"capacity[{p['profile']}]"
        if "controlled_qps" in p:
            out[f"{key}.controlled_qps"] = float(p["controlled_qps"])
        if "best_static_qps" in p:
            out[f"{key}.best_static_qps"] = float(p["best_static_qps"])
    return out


def compare(baseline: dict, fresh: dict, tolerance: float) -> list:
    """Return a list of human-readable failure strings (empty = pass)."""
    base_m = collect_metrics(baseline)
    fresh_m = collect_metrics(fresh)
    failures = []
    for key in sorted(base_m):
        base_v = base_m[key]
        if key not in fresh_m:
            failures.append(
                f"MISSING {key}: present in baseline ({base_v:.0f} qps) "
                f"but absent from the fresh run — did its sweep run?")
            continue
        fresh_v = fresh_m[key]
        floor = base_v * (1.0 - tolerance)
        if fresh_v < floor:
            pct = (fresh_v / base_v - 1.0) * 100.0
            failures.append(
                f"REGRESSION in {key}: {fresh_v:.0f} qps is {pct:+.1f}% "
                f"vs baseline {base_v:.0f} qps "
                f"(floor {floor:.0f} at tolerance {tolerance:.0%})")
    for key in sorted(set(fresh_m) - set(base_m)):
        print(f"note: new metric {key} = {fresh_m[key]:.0f} qps "
              f"(no baseline yet)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_endtoend.json to compare against")
    ap.add_argument("--fresh", required=True,
                    help="freshly generated BENCH_endtoend.json")
    ap.add_argument("--tolerance", type=float, default=0.15, metavar="FRAC",
                    help="allowed fractional qps drop per section "
                         "(default: 0.15)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    failures = compare(baseline, fresh, args.tolerance)
    n = len(collect_metrics(baseline))
    if failures:
        print(f"bench regression check: {len(failures)} failure(s) "
              f"across {n} baseline metric(s)")
        for msg in failures:
            print(f"  {msg}")
        return 1
    print(f"bench regression check: OK ({n} baseline metric(s) within "
          f"{args.tolerance:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Paper Fig. 11: latency/throughput Pareto front over configurations."""
from __future__ import annotations

from benchmarks.common import emit
from benchmarks.fig7_10_parallel import _stage_times
from repro.core.deployment import Config, pareto, sweep


def run():
    st = _stage_times()
    cfgs = [Config(p, w, k, e)
            for p in (1, 2, 4) for w in (1, 2, 4)
            for k in (1, 2, 4) for e in (1, 2, 4)
            if w >= k and p >= w and k * e <= 4]
    perfs = sweep(cfgs, st, [4096])
    front = pareto(perfs)
    for p in front:
        emit(f"fig11/front_{p.config.label().replace(' ', '')}",
             p.latency_us, f"qps={p.throughput_qps:.3e}")
    # the paper's selection logic: best config under a latency cap,
    # and best config above a throughput floor
    floor = sorted((p for p in perfs
                    if p.throughput_qps >= 0.5 * max(
                        q.throughput_qps for q in perfs)),
                   key=lambda p: p.latency_us)[0]
    emit("fig11/best_under_throughput_floor", floor.latency_us,
         f"config={floor.config.label()};qps={floor.throughput_qps:.3e}")
    return front


if __name__ == "__main__":
    run()

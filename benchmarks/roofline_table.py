"""Roofline table (this assignment's §Roofline): three terms per
(arch x shape x mesh) cell from the dry-run artifacts."""
from __future__ import annotations

from pathlib import Path

from benchmarks.common import emit
from repro.launch.roofline import load_all, table_markdown

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def run():
    rows = load_all(ART)
    if not rows:
        emit("roofline/missing", 0.0,
             "run: python -m repro.launch.dryrun --all --mesh both")
        return []
    rows.sort(key=lambda r: (r.mesh, r.arch, r.shape))
    for r in rows:
        emit(f"roofline/{r.arch}_{r.shape}_{r.mesh}", r.step_s * 1e6,
             f"dom={r.dominant};comp={r.compute_s:.4g};mem={r.memory_s:.4g}"
             f";coll={r.collective_s:.4g};useful={r.usefulness:.2f}"
             f";mfu_bound={r.mfu_bound:.3f}")
    out = Path(ART).parent / "roofline_table.md"
    out.write_text(table_markdown(rows))
    emit("roofline/table_written", 0.0, str(out))
    return rows


if __name__ == "__main__":
    run()

"""Where requests spend their time: per-stage trace attribution across the
paper's box shapes, plus the tracing overhead budget.

The paper's §5–6 post-mortem is a tracing argument: aggregate throughput
looked acceptable while every request actually sat in the host-side queue,
so the accelerator win was gone before the device stage even started. This
harness reproduces that diagnosis with ``repro.trace`` on the simulated
box shapes (``SIM_PROFILES``):

- **weak_host, overdriven** — offered load ~2x the serial-host capacity of
  the f1.2xlarge-style box: the trace's dominant stage must be
  ``queue_wait`` (requests queue behind the saturated host prepare path;
  the device stage is a footnote in the same timeline).
- **balanced, comfortable** — the c5.12xlarge-style box under moderate
  load: ``device_execute`` dominates, queue wait and encode are small —
  the regime where the accelerator is actually the thing being paid for.

Each point cross-checks the TraceReport against the RunReport computed
from the same run (identical timestamps -> identical percentiles) and
records both attributions. A separate measurement runs the identical
replay twice — ``trace=None`` vs ``trace=True`` — and reports the
throughput overhead of tracing (acceptance: < 1%; the disabled default is
bit-identical by construction and costs nothing).

Finally a 4-replica run with the capacity controller attached exports a
Chrome ``trace_event`` file (``artifacts/fig15_chrome_trace.json``, load
in ``chrome://tracing`` / Perfetto): every lifecycle stage plus the
controller's actions on one timeline.

Run directly (``--smoke`` shrinks the load for CI):

    PYTHONPATH=src python benchmarks/fig15_trace.py [--smoke]
"""
import json
import os
import time

try:
    from benchmarks.common import emit
except ModuleNotFoundError:     # run as a file: benchmarks/fig15_trace.py
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.common import emit

# (profile, expected dominant stage, offered qps, serving knobs): the two
# regimes of the paper's diagnosis
SCENARIOS = (
    dict(profile="weak_host", expect="queue_wait", qps=3000.0, n=400,
         replicas=2, target_batch=8, deadline=0.005, max_queue=128),
    dict(profile="balanced", expect="device_execute", qps=400.0, n=160,
         replicas=4, target_batch=8, deadline=0.002, max_queue=64),
)

OVERHEAD_N = 512            # replayed requests per overhead measurement
OVERHEAD_REPEATS = 7
CHROME_EXPORT = os.path.join("artifacts", "fig15_chrome_trace.json")

# structured points for the BENCH_endtoend.json "trace" section
TRACE_POINTS = []


def _stage_ms(trep, stage):
    st = trep.stages.get(stage)
    return st.mean_ms if st is not None and st.n else 0.0


def dominance_sweep(*, smoke=False):
    """Live overdriven/comfortable runs: the trace names the bottleneck."""
    from repro.serve import (OpenLoopGen, ServeConfig, SimServer,
                             SyntheticWorkload, build)

    scale = 0.25 if smoke else 1.0
    for sc in SCENARIOS:
        n = max(32, int(sc["n"] * scale))
        srv = build(ServeConfig(
            replicas=sc["replicas"], routing="least_loaded",
            target_batch=sc["target_batch"], deadline=sc["deadline"],
            max_queue=sc["max_queue"], policy="reject", trace=True,
            server_factory=lambda i, p=sc["profile"]:
                SimServer.from_profile(p)))
        workload = SyntheticWorkload(prompt_len=8, max_new_tokens=4, seed=3)
        sched = srv.session()
        gen = OpenLoopGen(workload, qps=sc["qps"], n=n, seed=15)
        gen.drive(sched)
        sched.result()
        rep = sched.report(offered_qps=sc["qps"])
        trep = sched.trace_report()
        dom = trep.dominant_stage()
        # the reconciliation the trace module promises: same timestamps,
        # same percentiles as the metrics layer
        recon_ok = (
            trep.counts.get("complete", 0) == rep.n_completed
            and trep.stages["queue_wait"].n == rep.breakdown["queue_wait"].n
            and abs(trep.stages["queue_wait"].p50_ms
                    - rep.breakdown["queue_wait"].p50_ms) < 1e-6
            and abs(_stage_ms(trep, "device_execute")
                    - rep.breakdown["device"].mean_ms) < 1e-6)
        point = dict(
            profile=sc["profile"], offered_qps=sc["qps"], n=n,
            expect_dominant=sc["expect"], dominant_stage=dom,
            dominance_ok=dom == sc["expect"],
            reconciles_with_run_report=recon_ok,
            queue_wait_ms=_stage_ms(trep, "queue_wait"),
            encode_ms=_stage_ms(trep, "encode"),
            device_execute_ms=_stage_ms(trep, "device_execute"),
            total_ms=_stage_ms(trep, "total"),
            n_completed=rep.n_completed, n_rejected=rep.n_rejected,
            n_spans=trep.n_spans, n_dropped=trep.n_dropped,
            per_replica={str(k): v.as_dict()
                         for k, v in trep.per_replica.items()},
        )
        TRACE_POINTS.append(point)
        emit(f"fig15_{sc['profile']}",
             _stage_ms(trep, "total") * 1e3,
             f"dominant={dom} (expect {sc['expect']}) "
             f"queue={point['queue_wait_ms']:.1f}ms "
             f"encode={point['encode_ms']:.1f}ms "
             f"device={point['device_execute_ms']:.1f}ms "
             f"reconciled={recon_ok}", **point)


def overhead_measurement(*, smoke=False):
    """The acceptance claim is about the *disabled* path: ``trace=None``
    (the default) must be bit-identical to the pre-trace stack with <1%
    throughput overhead — every emission site is an ``if tracer is not
    None`` guard around otherwise-unchanged code. With no pre-trace
    binary to race, the measurable statement is that two interleaved arms
    of identical ``trace=None`` runs are statistically identical (their
    delta is the noise floor the guards hide under), and that outputs
    with tracing on are bit-identical to off. The tracing-*on* wall-clock
    delta is reported informationally (it is genuinely nonzero: ~350
    span emissions against a sleep-calibrated simulator)."""
    import statistics

    import numpy as np

    from repro.serve import ServeConfig, SimServer, build, sim_requests

    n = 256 if smoke else OVERHEAD_N
    reqs = sim_requests(n, max_new_tokens=4)

    def run_once(trace):
        # big batches -> few long sleeps: the simulator's wall time is
        # sleep-dominated, and OS sleep quantisation is the noise floor
        # this comparison sits on, so fewer sleeps = a quieter floor
        srv = build(ServeConfig(
            replicas=2, routing="sticky", target_batch=16, deadline=0.01,
            trace=trace,
            server_factory=lambda i: SimServer(host_ms_per_batch=2.0,
                                               device_ms_per_batch=4.0)))
        with srv:
            t0 = time.perf_counter()
            outs = srv.serve(reqs, mode="pipelined")
            dt = time.perf_counter() - t0
        assert len(outs) == n
        return dt, outs

    arm_a, arm_b, arm_on = [], [], []
    outs_off = outs_on = None
    for _ in range(OVERHEAD_REPEATS):
        dt, outs_off = run_once(None)
        arm_a.append(dt)
        dt, outs_on = run_once(True)
        arm_on.append(dt)
        dt, _ = run_once(None)
        arm_b.append(dt)
    # identical code in both arms: compare noise *floors* (min), which
    # converge much faster than medians under shared-machine jitter
    a = min(arm_a)
    b = min(arm_b)
    on = statistics.median(arm_on)
    off = statistics.median(arm_a + arm_b)
    disabled_overhead = abs(a / b - 1.0)
    traced_delta = on / off - 1.0

    by_rid = {c.rid: c for c in outs_off}
    bit_identical = len(outs_on) == len(outs_off) and all(
        np.array_equal(by_rid[c.rid].tokens, c.tokens) for c in outs_on)

    point = dict(n=n, off_s=off, on_s=on,
                 disabled_overhead_fraction=disabled_overhead,
                 traced_delta_fraction=traced_delta,
                 bit_identical=bit_identical,
                 overhead_ok=disabled_overhead < 0.01 and bit_identical)
    TRACE_POINTS.append({"overhead": point})
    emit("fig15_trace_overhead", off / n * 1e6,
         f"trace=None arms delta={disabled_overhead * 100:.2f}% "
         f"(budget <1%) bit_identical={bit_identical} "
         f"[tracing on: {on * 1e3:.1f}ms vs {off * 1e3:.1f}ms, "
         f"{traced_delta * 100:+.2f}%]", **point)


def chrome_export(path=CHROME_EXPORT, *, smoke=False):
    """4-replica controlled run -> Chrome trace_event artifact."""
    from repro.serve import (PhasedOpenLoopGen, ServeConfig, SimServer,
                             SyntheticWorkload, build)

    scale = 0.25 if smoke else 1.0
    phases = [(0.6 * scale, 800.0), (1.2 * scale, 2400.0),
              (0.6 * scale, 1600.0)]
    srv = build(ServeConfig(
        replicas=4, routing="least_loaded", target_batch=4, deadline=0.01,
        max_queue=64, policy="shed_oldest", trace=True,
        capacity={"window_s": 0.05 if smoke else 0.1, "confirm": 2,
                  "min_batch": 4, "max_batch": 32},
        server_factory=lambda i: SimServer.from_profile("weak_host")))
    workload = SyntheticWorkload(prompt_len=8, max_new_tokens=4, seed=3)
    sched = srv.session()
    PhasedOpenLoopGen(workload, phases, seed=14).drive(sched)
    sched.result()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    srv.export_trace(path)
    with open(path) as f:
        events = json.load(f)["traceEvents"]
    stages = {e["name"] for e in events if e.get("ph") in ("X", "i", "b")}
    n_controller = sum(e.get("name") == "controller" for e in events)
    replica_lanes = sorted({e["args"]["name"] for e in events
                            if e.get("ph") == "M"
                            and e.get("name") == "thread_name"
                            and e["args"]["name"].startswith("replica-")})
    point = dict(path=path, n_events=len(events),
                 stages=sorted(stages), n_controller_events=n_controller,
                 replica_lanes=replica_lanes,
                 lifecycle_complete=bool(
                     {"submit", "queue_wait", "encode", "dispatch",
                      "device_execute", "complete"} <= stages))
    TRACE_POINTS.append({"chrome_export": point})
    emit("fig15_chrome_export", float(len(events)),
         f"{len(events)} events -> {path} "
         f"stages={len(stages)} controller={n_controller} "
         f"replicas={len(replica_lanes)}", **point)


def run():
    dominance_sweep()
    overhead_measurement()
    chrome_export()


if __name__ == "__main__":
    import argparse

    from benchmarks import common

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="shrunk load (CI): fewer requests, short phases")
    ap.add_argument("--out", default=CHROME_EXPORT, metavar="PATH",
                    help="Chrome trace_event artifact path "
                         f"(default: {CHROME_EXPORT})")
    ap.add_argument("--json", nargs="?", const="BENCH_endtoend.json",
                    default="BENCH_endtoend.json", metavar="PATH",
                    help="merge structured results into PATH (default: "
                         "BENCH_endtoend.json)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    dominance_sweep(smoke=args.smoke)
    overhead_measurement(smoke=args.smoke)
    chrome_export(args.out, smoke=args.smoke)
    payload = {"suites": ["fig15"], "failed": [],
               "results": common.RESULTS, "trace": TRACE_POINTS}
    try:
        # merge into an existing run, preserving every section other
        # harnesses wrote (cache, capacity, and anything future)
        with open(args.json) as f:
            prev = json.load(f)
        payload["suites"] = sorted(set(prev.get("suites", [])) | {"fig15"})
        payload["failed"] = prev.get("failed", [])
        payload["results"] = prev.get("results", []) + common.RESULTS
        payload["trace"] = prev.get("trace", []) + TRACE_POINTS
        for key, val in prev.items():
            payload.setdefault(key, val)
    except (OSError, ValueError):
        pass
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)

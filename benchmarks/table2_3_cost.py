"""Paper Tables 2-3: deployment cost estimates (reproduced exactly from the
paper's unit prices) + the TPU v5e re-parameterisation of the same
CPU:accelerator balance analysis."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.cost_model import (PAPER_TABLE2_TOTALS, TPUCostParams,
                                   table2, table3, tpu_balance)


def run():
    ok = True
    for d in table2():
        exp = PAPER_TABLE2_TOTALS.get(d.name)
        dev = abs(d.total_usd - exp) / exp if exp else 0.0
        ok &= dev < 0.03
        emit(f"table2/{d.name.replace(' ', '_').replace('/', '-')}", 0.0,
             f"total=${d.total_usd / 1e6:.2f}M;paper=${(exp or 0) / 1e6:.2f}M"
             f";dev={dev:.1%}")
    emit("table2/validated_against_paper", 0.0, f"ok={ok}")

    for d in table3():
        emit(f"table3/{d.name.replace(' ', '_').replace('/', '-')}", 0.0,
             f"total=${d.total_usd / 1e6:.2f}M")

    # TPU v5e: same imbalance analysis on our target hardware
    p = TPUCostParams()
    for qps in (2e8, 2e9, 2e10):
        r = tpu_balance(p, qps)
        emit(f"tpu_balance/qps{qps:.0e}", 0.0,
             f"chips={r['chips_bought']:.1f};util={r['accel_utilisation']:.2f}"
             f";cost_ratio_vs_cpu={r['cost_ratio_accel_vs_cpu']:.2f}")
    return ok


if __name__ == "__main__":
    run()

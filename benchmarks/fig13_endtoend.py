"""End-to-end saturation/imbalance sweep (the paper's §5–6 phenomenon as a
single harness, extending Figs 7–12 from microbenchmarks to the full
submission pipeline).

Protocol: measure the server's full-batch service rate once, then sweep
open-loop offered load at fractions of that capacity through the
AsyncScheduler. At low offered load the deadline flushes small batches, so
per-request device cost is high and the system saturates well below the
nominal full-batch capacity — the paper's "the host cannot generate enough
load to realise the accelerator's throughput" regime. As offered load
rises, batches fill and achieved throughput climbs toward capacity until
queueing dominates latency and backpressure starts rejecting. Dialing up
``SyntheticWorkload`` host work per request (prompt length, MCT queries)
shifts the bottleneck host-side and the device-idle-fraction climbs.

The replica sweep (``--replicas``) extends the axis from one accelerator to
many: N simulated engine replicas (``repro.serve.SimServer`` — wall-clock
host/device costs, real thread overlap) behind the single admission path.
Aggregate achieved throughput scales with replica count until the *serial
host prepare path* saturates — the paper's kernels-per-accelerator axis at
serving granularity, terminating in the predicted CPU-bound plateau.

The cache sweep (``--cache``) shows the application-level way past that
plateau: repeat-heavy traffic (Zipf key reuse, ``--repeat-alpha``) served
with the content-addressed result cache + coalescer on vs off. Cache-off
pins at the ~host-cap qps regardless of repetition; cache-on executes only
the unique leaders, so effective throughput climbs with hit rate — above
the serial-host cap, with the recorded hit/coalesce counters proving no
extra hardware was involved.

Emits one CSV row per offered-load / replica point; with ``run.py --json``
(or running this file directly) the full latency breakdown + idle fraction
+ per-replica stats land in BENCH_endtoend.json.
"""
import time

try:
    from benchmarks.common import emit
except ModuleNotFoundError:     # run as a file: benchmarks/fig13_endtoend.py
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.common import emit

# sweep grid: offered load as a multiple of measured capacity
LOAD_FRACTIONS = (0.25, 0.5, 1.0, 2.0, 4.0)
TARGET_BATCH = 8
MAX_QUEUE = 16
# must exceed queue depth PLUS pipeline capacity (pipeline_depth+1 batches
# in flight), or the overload points can never fill the admission queue
# and the rejection regime is structurally unreachable
N_PER_POINT = 64

# replica sweep: host prepare 3 ms/batch (serial, dispatcher thread) vs
# device execute 8 ms/batch (parallel across replicas) -> ideal scaling to
# ~2.7 replicas, then the host-bound plateau at 1/3ms = 333 batches/s
REPLICA_COUNTS = (1, 2, 4)
SIM_HOST_MS = 3.0
SIM_DEVICE_MS = 8.0
SIM_N_BATCHES = 48

# cache sweep: Zipf key-reuse skews (0 = uniform over the key population)
# x cache on/off, two waves of the same key population (warm, then repeat)
CACHE_ALPHAS = (0.0, 0.6, 1.1)
CACHE_REPLICAS = 4
# hit-rate sweep points for the BENCH_endtoend.json "cache" section
CACHE_POINTS = []

# routing sweep: repeat-heavy Zipf traffic (alpha >= 1.1) recomputed wave
# after wave (TTL expiry between waves), with and without an injected
# straggler, under each routing policy. Device cost uses SimServer's
# warm-content model so *placement* matters: recomputing a key on the
# replica that produced it runs at warm cost
ROUTING_POLICIES_SWEPT = ("least_loaded", "sticky", "hit_aware")
ROUTING_ALPHA = 1.1
ROUTING_REPLICAS = 4
ROUTING_WAVES = 3
ROUTING_N = 256                 # requests per wave
ROUTING_UNIQUE = 96             # Zipf key population
ROUTING_TTL = 5.0               # logical seconds; waves arrive 4x apart
ROUTING_STRAGGLER_S = 0.05      # injected delay per batch on replica 0
ROUTING_WARM_FACTOR = 0.25      # warm recompute costs 25% of cold
# sweep points for the BENCH_endtoend.json "routing" section
ROUTING_POINTS = []


def _server():
    from repro.serve import ServeConfig, build
    return build(ServeConfig(model="llama3.2-3b", max_seq=48,
                             target_batch=TARGET_BATCH, deadline=0.01,
                             max_queue=MAX_QUEUE, policy="reject"))


def _capacity_qps(srv, workload) -> float:
    """Service rate with full target-sized batches (requests/second)."""
    srv.warmup((1, 2, 4, TARGET_BATCH))      # pre-compile bucket sizes
    reqs = workload.build(TARGET_BATCH, rid_base=10_000)
    t0 = time.perf_counter()
    srv.engine.generate_batch(reqs)
    dt = time.perf_counter() - t0
    return TARGET_BATCH / dt


def replica_sweep(replica_counts=REPLICA_COUNTS):
    """Host-device simulation: aggregate throughput vs replica count."""
    from repro.serve import ServeConfig, SimServer, build, sim_requests

    base_qps = None
    for r in replica_counts:
        cfg = ServeConfig(
            replicas=r, routing="least_loaded",
            target_batch=TARGET_BATCH, deadline=1.0,
            server_factory=lambda i: SimServer(
                host_ms_per_batch=SIM_HOST_MS,
                device_ms_per_batch=SIM_DEVICE_MS))
        srv = build(cfg)
        reqs = sim_requests(SIM_N_BATCHES * TARGET_BATCH, max_new_tokens=4)
        t0 = time.perf_counter()
        outs = srv.serve(reqs, mode="pipelined")
        dt = time.perf_counter() - t0
        qps = len(outs) / dt
        if base_qps is None:
            base_qps = qps
        rep = srv.report()
        # host-bound when the dispatcher can no longer outrun the replicas:
        # the serial prepare path caps batch rate at 1/host_ms
        host_cap_qps = 1e3 / SIM_HOST_MS * TARGET_BATCH
        emit(f"fig13_replicas_{r}", dt / len(outs) * 1e6,
             f"replicas={r} achieved={qps:.0f}qps "
             f"scale={qps / base_qps:.2f}x "
             f"host_cap={host_cap_qps:.0f}qps "
             f"idle={rep.device_idle_fraction:.2f}",
             replicas=r, achieved_qps=qps, scale=qps / base_qps,
             host_cap_qps=host_cap_qps, report=rep.as_dict())


def cache_sweep(repeat_alphas=CACHE_ALPHAS, replicas=CACHE_REPLICAS):
    """Repeat-heavy traffic x cache on/off: the hit-rate -> throughput
    curve against the serial-host prepare cap.

    Two waves of the same Zipf key population (fresh rids, identical
    contents via ``content_seed``): wave 1 warms the cache, wave 2 is
    where real traffic's repetition pays. Effective qps counts every
    completed request over the total wall time.
    """
    from repro.serve import (CacheConfig, ServeConfig, SimServer, build,
                             sim_requests)

    n = SIM_N_BATCHES * TARGET_BATCH
    uniq = max(1, n // 4)
    host_cap_qps = 1e3 / SIM_HOST_MS * TARGET_BATCH
    for alpha in repeat_alphas:
        for cached in (False, True):
            cfg = ServeConfig(
                replicas=replicas, routing="least_loaded",
                target_batch=TARGET_BATCH, deadline=1.0,
                cache=CacheConfig() if cached else None,
                server_factory=lambda i: SimServer(
                    host_ms_per_batch=SIM_HOST_MS,
                    device_ms_per_batch=SIM_DEVICE_MS))
            srv = build(cfg)
            waves = [sim_requests(n, max_new_tokens=4, rid_base=w * n,
                                  unique_keys=uniq, repeat_alpha=alpha,
                                  content_seed=101)
                     for w in range(2)]
            t0 = time.perf_counter()
            outs = []
            for wave in waves:
                outs.extend(srv.serve(wave, mode="pipelined"))
            dt = time.perf_counter() - t0
            qps = len(outs) / dt
            rep = srv.report()
            # the serial dispatcher paid SIM_HOST_MS per *executed* batch;
            # everything else was served from content, not hardware
            host_s = len(rep.batch_sizes) * SIM_HOST_MS * 1e-3
            host_util = host_s / dt if dt > 0 else 0.0
            hit_rate = rep.cache.get("hit_rate", 0.0) if rep.cache else 0.0
            tag = "on" if cached else "off"
            point = dict(repeat_alpha=alpha, cached=cached,
                         n_requests=len(outs), effective_qps=qps,
                         host_cap_qps=host_cap_qps, hit_rate=hit_rate,
                         host_prepare_utilization=host_util,
                         device_idle_fraction=rep.device_idle_fraction,
                         n_batches_executed=len(rep.batch_sizes),
                         cache=dict(rep.cache))
            CACHE_POINTS.append(point)
            emit(f"fig13_cache_a{alpha:g}_{tag}", dt / len(outs) * 1e6,
                 f"alpha={alpha:g} cache={tag} "
                 f"qps={qps:.0f} (host_cap={host_cap_qps:.0f}) "
                 f"hit={hit_rate:.2f} host_util={host_util:.2f} "
                 f"idle={rep.device_idle_fraction:.2f}",
                 report=rep.as_dict(), **point)


def routing_sweep(policies=ROUTING_POLICIES_SWEPT,
                  repeat_alpha=ROUTING_ALPHA):
    """Routing-policy shoot-out on repeat-heavy recompute traffic.

    ``ROUTING_WAVES`` waves of the same Zipf key population arrive with
    gaps larger than the cache TTL, so every wave past the first
    recomputes expired content and the router decides *where*. Each
    replica's SimServer runs with the warm-content model
    (``warm_factor``): recomputing a key on the replica that produced it
    is cheap, elsewhere it is cold — which is precisely the placement
    signal ``hit_aware`` reads from the cache's affinity tombstones.

    Two scenarios per policy: ``repeat`` (all replicas healthy — affinity
    placement should win on warmth) and ``straggler`` (replica 0 delayed
    via ``DelayInjector`` — hit-aware must *spill away* from the slow
    owner instead of chasing warmth into it). Outputs stay bit-identical
    across policies; only wall time moves.
    """
    from repro.ft.failures import DelayInjector
    from repro.serve import (CacheConfig, ServeConfig, SimServer, build,
                             sim_requests)
    import numpy as np

    scenarios = (("repeat", None),
                 ("straggler", DelayInjector({0: ROUTING_STRAGGLER_S})))
    for scenario, delay in scenarios:
        for policy in policies:
            cfg = ServeConfig(
                replicas=ROUTING_REPLICAS, routing=policy,
                target_batch=TARGET_BATCH, deadline=1.0,
                cache=CacheConfig(ttl=ROUTING_TTL),
                # two full batches of outstanding-work gap spill (one
                # 8x(8+4) batch is 96 work units): a one-batch gap is
                # normal pipelining, not imbalance. Straggler avoidance
                # rides the EWMA, which the group persists across waves
                spill_threshold=128,
                delay=delay,
                server_factory=lambda i: SimServer(
                    host_ms_per_batch=1.0,
                    device_ms_per_batch=0.5,
                    device_ms_per_token=1.0,
                    warm_factor=ROUTING_WARM_FACTOR))
            srv = build(cfg)
            t0 = time.perf_counter()
            outs = []
            for w in range(ROUTING_WAVES):
                # fresh rids per wave, identical contents (content_seed);
                # the +w+1 rid offset keeps sticky off replica 0 so the
                # straggler scenario is conservative for the comparison.
                # Arrival gaps (20 s logical) exceed the 5 s TTL, so every
                # wave past the first recomputes through the router.
                base = w * 20.0
                # each wave opens with w batches of never-repeating filler
                # (fresh uniform contents per wave): background traffic
                # that shifts where in the round-robin order the repeat
                # keys arrive. Content-blind placement then lands them on
                # a different (cold) replica each wave — only ownership-
                # tracking routing can keep recomputes warm
                fill = sim_requests(
                    w * TARGET_BATCH, max_new_tokens=4,
                    rid_base=(10 + w) * 100_000,
                    content_seed=5000 + 17 * w,
                    arrivals=base + np.arange(w * TARGET_BATCH) * 1e-3)
                wave = sim_requests(
                    ROUTING_N, max_new_tokens=4,
                    rid_base=w * ROUTING_N + w + 1,
                    unique_keys=ROUTING_UNIQUE, repeat_alpha=repeat_alpha,
                    content_seed=211,
                    arrivals=base + (w * TARGET_BATCH
                                     + np.arange(ROUTING_N)) * 1e-3)
                outs.extend(srv.serve(fill + wave, mode="pipelined"))
            dt = time.perf_counter() - t0
            qps = len(outs) / dt
            rep = srv.report()
            point = dict(scenario=scenario, policy=policy,
                         repeat_alpha=repeat_alpha,
                         n_requests=len(outs), effective_qps=qps,
                         affinity_hits=rep.affinity_hits,
                         affinity_spills=rep.affinity_spills,
                         n_batches_executed=len(rep.batch_sizes),
                         replica_batches={str(k): v.n_batches for k, v in
                                          sorted(rep.per_replica.items())})
            ROUTING_POINTS.append(point)
            emit(f"fig13_routing_{scenario}_{policy}",
                 dt / len(outs) * 1e6,
                 f"scenario={scenario} policy={policy} qps={qps:.0f} "
                 f"affinity={rep.affinity_hits}hit/"
                 f"{rep.affinity_spills}spill "
                 f"batches={len(rep.batch_sizes)}",
                 report=rep.as_dict(), **point)


def run():
    from repro.serve import OpenLoopGen, SyntheticWorkload

    srv = _server()
    workload = SyntheticWorkload(vocab=srv.engine.cfg.vocab, prompt_len=6,
                                 max_new_tokens=3, seed=1)
    cap = _capacity_qps(srv, workload)

    for frac in LOAD_FRACTIONS:
        qps = cap * frac
        sched = srv.session()            # fresh live session per point
        gen = OpenLoopGen(workload, qps=qps, n=N_PER_POINT,
                          seed=int(frac * 100))
        gen.drive(sched)
        sched.result()
        rep = sched.report(offered_qps=qps)
        t = rep.breakdown["total"]
        emit(f"fig13_load_{frac:g}x",
             t.p50_ms * 1e3,
             f"offered={qps:.0f}qps achieved={rep.achieved_qps:.0f}qps "
             f"idle={rep.device_idle_fraction:.2f} "
             f"rej={rep.n_rejected} p99={t.p99_ms:.0f}ms",
             report=rep.as_dict())

    # baseline vs pipelined on the identical stream: the host/device
    # overlap win of the async pipeline (fig13 inset)
    reqs = OpenLoopGen(workload, qps=cap, n=24, seed=5).requests()
    t0 = time.perf_counter()
    srv.serve(reqs, mode="sync")
    sync_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    srv.serve(reqs, mode="pipelined")
    pipe_s = time.perf_counter() - t0
    emit("fig13_pipeline_overlap", pipe_s * 1e6,
         f"sync={sync_s * 1e3:.0f}ms pipelined={pipe_s * 1e3:.0f}ms "
         f"speedup={sync_s / pipe_s:.2f}x",
         sync_s=sync_s, pipelined_s=pipe_s)

    # replica scaling on top of the same admission path (simulated engines)
    replica_sweep()

    # repeat traffic with/without the result cache: the way past the
    # serial-host plateau the replica sweep just demonstrated
    cache_sweep()


if __name__ == "__main__":
    import argparse
    import json

    from benchmarks import common

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--replicas", nargs="+", type=int, default=None,
                    metavar="N",
                    help="run only the replica sweep at these counts "
                         "(e.g. --replicas 1 2 4)")
    ap.add_argument("--cache", action="store_true",
                    help="run only the cache hit-rate sweep "
                         "(repeat traffic x cache on/off)")
    ap.add_argument("--repeat-alpha", nargs="+", type=float, default=None,
                    metavar="A",
                    help="Zipf key-reuse skews for the cache sweep "
                         f"(default: {' '.join(map(str, CACHE_ALPHAS))})")
    ap.add_argument("--routing", action="store_true",
                    help="run only the routing-policy sweep (repeat-heavy "
                         "recompute traffic x least_loaded/sticky/"
                         "hit_aware, with and without a straggler)")
    ap.add_argument("--json", nargs="?", const="BENCH_endtoend.json",
                    default="BENCH_endtoend.json", metavar="PATH",
                    help="write structured results (default: "
                         "BENCH_endtoend.json)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.cache:
        cache_sweep(tuple(args.repeat_alpha) if args.repeat_alpha
                    else CACHE_ALPHAS)
    elif args.routing:
        routing_sweep(repeat_alpha=args.repeat_alpha[0]
                      if args.repeat_alpha else ROUTING_ALPHA)
    elif args.replicas:
        replica_sweep(tuple(args.replicas))
    else:
        run()
    payload = {"suites": ["fig13"], "failed": [],
               "results": common.RESULTS, "cache": CACHE_POINTS,
               "routing": ROUTING_POINTS}
    try:
        # merge into an existing run (CI writes the load/replica sweep via
        # benchmarks.run first, then adds the cache sweep on top)
        with open(args.json) as f:
            prev = json.load(f)
        payload["suites"] = sorted(set(prev.get("suites", [])) | {"fig13"})
        payload["failed"] = prev.get("failed", [])
        payload["results"] = prev.get("results", []) + common.RESULTS
        payload["cache"] = prev.get("cache", []) + CACHE_POINTS
        payload["routing"] = prev.get("routing", []) + ROUTING_POINTS
        for key, val in prev.items():
            # sections other harnesses wrote (capacity, trace, ...)
            payload.setdefault(key, val)
    except (OSError, ValueError):
        pass
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)

"""End-to-end saturation/imbalance sweep (the paper's §5–6 phenomenon as a
single harness, extending Figs 7–12 from microbenchmarks to the full
submission pipeline).

Protocol: measure the server's full-batch service rate once, then sweep
open-loop offered load at fractions of that capacity through the
AsyncScheduler. At low offered load the deadline flushes small batches, so
per-request device cost is high and the system saturates well below the
nominal full-batch capacity — the paper's "the host cannot generate enough
load to realise the accelerator's throughput" regime. As offered load
rises, batches fill and achieved throughput climbs toward capacity until
queueing dominates latency and backpressure starts rejecting. Dialing up
``SyntheticWorkload`` host work per request (prompt length, MCT queries)
shifts the bottleneck host-side and the device-idle-fraction climbs.

Emits one CSV row per offered-load point; with ``run.py --json`` the full
latency breakdown + idle fraction lands in BENCH_endtoend.json.
"""
import time

from benchmarks.common import emit

# sweep grid: offered load as a multiple of measured capacity
LOAD_FRACTIONS = (0.25, 0.5, 1.0, 2.0, 4.0)
TARGET_BATCH = 8
MAX_QUEUE = 16
# must exceed queue depth PLUS pipeline capacity (pipeline_depth+1 batches
# in flight), or the overload points can never fill the admission queue
# and the rejection regime is structurally unreachable
N_PER_POINT = 64


def _server():
    from repro.configs.base import get_config
    from repro.serve import LMServer
    cfg = get_config("llama3.2-3b").reduced()
    return LMServer(cfg, max_seq=48)


def _capacity_qps(server, workload) -> float:
    """Service rate with full target-sized batches (requests/second)."""
    server.warmup((1, 2, 4, TARGET_BATCH))   # pre-compile bucket sizes
    reqs = workload.build(TARGET_BATCH, rid_base=10_000)
    t0 = time.perf_counter()
    server.generate_batch(reqs)
    dt = time.perf_counter() - t0
    return TARGET_BATCH / dt


def run():
    from repro.serve import AsyncScheduler, OpenLoopGen, SyntheticWorkload

    server = _server()
    workload = SyntheticWorkload(vocab=server.cfg.vocab, prompt_len=6,
                                 max_new_tokens=3, seed=1)
    cap = _capacity_qps(server, workload)

    for frac in LOAD_FRACTIONS:
        qps = cap * frac
        sched = AsyncScheduler(server, target_batch=TARGET_BATCH,
                               deadline=0.01, max_queue=MAX_QUEUE,
                               policy="reject")
        gen = OpenLoopGen(workload, qps=qps, n=N_PER_POINT,
                          seed=int(frac * 100))
        gen.drive(sched)
        sched.result()
        rep = sched.report(offered_qps=qps)
        t = rep.breakdown["total"]
        emit(f"fig13_load_{frac:g}x",
             t.p50_ms * 1e3,
             f"offered={qps:.0f}qps achieved={rep.achieved_qps:.0f}qps "
             f"idle={rep.device_idle_fraction:.2f} "
             f"rej={rep.n_rejected} p99={t.p99_ms:.0f}ms",
             report=rep.as_dict())

    # baseline vs pipelined on the identical stream: the host/device
    # overlap win of the async pipeline (fig13 inset)
    reqs = OpenLoopGen(workload, qps=cap, n=24, seed=5).requests()
    t0 = time.perf_counter()
    server.serve_stream(reqs, target_batch=TARGET_BATCH, deadline=0.01)
    sync_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    server.serve_stream(reqs, target_batch=TARGET_BATCH, deadline=0.01,
                        pipeline=True)
    pipe_s = time.perf_counter() - t0
    emit("fig13_pipeline_overlap", pipe_s * 1e6,
         f"sync={sync_s * 1e3:.0f}ms pipelined={pipe_s * 1e3:.0f}ms "
         f"speedup={sync_s / pipe_s:.2f}x",
         sync_s=sync_s, pipelined_s=pipe_s)


if __name__ == "__main__":
    run()

"""Paper Figs. 7-10: the four parallel-configuration series over
(p processes, w workers, k kernels, e engines/kernel), from measured stage
costs + the calibrated deployment model.

Fig 7: engines per kernel (latency down, sub-linear throughput)
Fig 8: uniform scaling (throughput up, per-request latency up)
Fig 9: many workers per kernel (XRT-scheduler serialisation)
Fig 10: many processes per worker (worker saturation at ~16 p/w)
"""
from __future__ import annotations

from benchmarks.common import emit, rule_system
from repro.core.aggregator import Batch
from repro.core.deployment import Config, evaluate
from repro.core.engine import ErbiumEngine
from repro.core.wrapper import measure_stage_times

BATCH = 4_096


def _stage_times():
    rs, table, qs, enc = rule_system(2)
    eng = ErbiumEngine(table, backend="ref")

    def make_batch(n):
        return Batch(0, [qs[i % len(qs)] for i in range(n)], [(0, -1)] * n)

    return measure_stage_times(eng, make_batch, (256, 1024, 4096),
                               repeats=2)


def run():
    st = _stage_times()
    series = {
        "fig7_engines": [Config(1, 1, 1, e) for e in (1, 2, 4)],
        "fig8_uniform": [Config(c, c, c, 1) for c in (1, 2, 4)],
        "fig9_workers_per_kernel": [Config(w, w, 1, 4)
                                    for w in (1, 2, 4, 8)],
        "fig10_procs_per_worker": [Config(p, 1, 1, 4)
                                   for p in (1, 2, 8, 16, 32)],
    }
    out = {}
    for name, cfgs in series.items():
        for c in cfgs:
            perf = evaluate(c, st, BATCH)
            emit(f"{name}/{c.label().replace(' ', '')}", perf.latency_us,
                 f"qps={perf.throughput_qps:.3e}")
            out[(name, c)] = perf
    # derived paper claims
    e1 = out[("fig7_engines", Config(1, 1, 1, 1))]
    e4 = out[("fig7_engines", Config(1, 1, 1, 4))]
    emit("fig7/4engines_speedup", 0.0,
         f"latency_ratio={e1.latency_us / e4.latency_us:.2f} "
         f"(sub-linear: <4 due to 30% clock derate)")
    p16 = out[("fig10_procs_per_worker", Config(16, 1, 1, 4))]
    p32 = out[("fig10_procs_per_worker", Config(32, 1, 1, 4))]
    emit("fig10/worker_saturation", 0.0,
         f"qps_gain_16to32={p32.throughput_qps / p16.throughput_qps:.2f} "
         f"(saturates ~1.0)")
    return out


if __name__ == "__main__":
    run()

"""Paper Fig. 6: execution time of an MCT request decomposed into stages
(queue/encode/dispatch/kernel/collect) as a function of batch size.

Reproduced phenomena: small batches dominated by dispatch overheads; large
batches dominated by the (linear) encoder, which exceeds kernel time.
"""
from __future__ import annotations

from benchmarks.common import emit, rule_system
from repro.core.aggregator import Batch
from repro.core.engine import ErbiumEngine
from repro.core.wrapper import measure_stage_times

BATCHES = (64, 256, 1024, 4096, 8192)


def run():
    rs, table, qs, enc = rule_system(2)
    # kernel stage = the XLA-compiled matcher (the Pallas kernel targets TPU
    # and is validated in interpret mode, which is not a timing proxy)
    eng = ErbiumEngine(table, backend="ref")

    def make_batch(n):
        sel = [qs[i % len(qs)] for i in range(n)]
        return Batch(0, sel, [(0, -1)] * n)

    times = measure_stage_times(eng, make_batch, BATCHES, repeats=3)
    for t in times:
        # project the kernel stage onto the TPU target (roofline: B*R*C
        # compare-AND ops on the VPU) — on this CPU the kernel stage runs
        # the same silicon as the encoder, which inverts the paper's ratio
        tpu_kernel_us = (t.batch * table.n_rules * table.n_cols * 3
                         / 100e12) * 1e6
        emit(f"fig6/b{t.batch}", t.total_us,
             f"encode={t.encode_us:.0f};dispatch={t.dispatch_us:.0f};"
             f"kernel={t.kernel_us:.0f};collect={t.collect_us:.0f};"
             f"kernel_tpu_proj={tpu_kernel_us:.1f}")
    big = times[-1]
    proj = (big.batch * table.n_rules * table.n_cols * 3 / 100e12) * 1e6
    emit("fig6/encoder_dominates_at_large_batch", 0.0,
         f"encode/kernel_tpu_proj={big.encode_us / max(proj, 1e-3):.0f} "
         f"(paper: encoder > kernel on the accelerator target)")
    return times


if __name__ == "__main__":
    run()

"""Paper Fig. 4: stand-alone engine throughput/latency vs batch size,
MCT v1 vs v2, 1/2/4 evaluation engines.

Reproduced phenomena: (i) latency flat until the pipeline saturates, then
throughput plateaus; (ii) v2 saturates LOWER than v1 (26 criteria -> 31
columns vs 22: bigger 'NFA'); (iii) engines scale sub-linearly.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, rule_system, time_us
from repro.kernels import ops

BATCHES = (256, 1024, 4096, 8192)


def run():
    rows = {}
    for version in (1, 2):
        rs, table, qs, enc = rule_system(version)
        dt = ops.device_table(table, tile_r=512)
        for n_eng in (1, 2, 4):
            for b in BATCHES:
                q = jnp.asarray(enc[:b], jnp.int32)
                us = time_us(ops.match_rules, q, dt, tile_b=256,
                             tile_r=512, n_engines=n_eng)
                qps = b / (us / 1e6)
                emit(f"fig4/v{version}_e{n_eng}_b{b}", us,
                     f"qps={qps:.3e}")
                rows[(version, n_eng, b)] = qps
    # derived claims
    v1 = rows[(1, 4, max(BATCHES))]
    v2 = rows[(2, 4, max(BATCHES))]
    emit("fig4/v2_vs_v1_saturated", 0.0,
         f"ratio={v2 / v1:.2f} (paper: 32M/40M = 0.80)")
    return rows


if __name__ == "__main__":
    run()

"""Shared benchmark fixtures: a production-shaped rule system (scaled to
this container) and timing helpers."""
from __future__ import annotations

import time
from functools import lru_cache

import jax
import numpy as np

from repro.core.compiler import compile_rules
from repro.core.encoder import encode_queries
from repro.core.engine import ErbiumEngine
from repro.core.rules import generate_queries, generate_rules

# scaled-down production shape (paper: 160k rules; CPU container: 4k)
N_RULES = 4_096
N_QUERIES = 8_192


@lru_cache(maxsize=None)
def rule_system(version: int):
    rs = generate_rules(N_RULES, version=version, seed=42)
    table = compile_rules(rs)
    qs = generate_queries(rs, N_QUERIES, seed=43)
    enc = encode_queries(table, qs)
    return rs, table, qs, enc


def time_us(fn, *args, repeats: int = 3, warmup: int = 1, **kw) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


# structured copies of every emitted row, for ``run.py --json`` trajectory
# files (BENCH_endtoend.json); ``extra`` carries suite-specific payloads
# such as the fig13 latency breakdown
RESULTS: list = []


def emit(name: str, us_per_call: float, derived: str, **extra):
    print(f"{name},{us_per_call:.1f},{derived}")
    row = {"name": name, "us_per_call": float(us_per_call),
           "derived": derived}
    if extra:
        row.update(extra)
    RESULTS.append(row)

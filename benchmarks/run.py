# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows. Usage: PYTHONPATH=src python -m benchmarks.run [--only fig4,...]
#                  [--json [PATH]]   (default PATH: BENCH_endtoend.json)
import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig4,table2")
    ap.add_argument("--json", nargs="?", const="BENCH_endtoend.json",
                    default=None, metavar="PATH",
                    help="also write structured results as JSON "
                         "(default: BENCH_endtoend.json) so future PRs "
                         "have a perf trajectory to compare against")
    args = ap.parse_args()

    from benchmarks import (common, fig4_throughput, fig6_overheads,
                            fig7_10_parallel, fig11_pareto, fig12_cpu_accel,
                            fig13_endtoend, fig14_capacity, fig15_trace,
                            roofline_table, table2_3_cost)
    suites = {
        "fig4": fig4_throughput.run,
        "fig6": fig6_overheads.run,
        "fig7_10": fig7_10_parallel.run,
        "fig11": fig11_pareto.run,
        "fig12": fig12_cpu_accel.run,
        "fig13": fig13_endtoend.run,
        "fig14": fig14_capacity.run,
        "fig15": fig15_trace.run,
        "table2": table2_3_cost.run,
        "roofline": roofline_table.run,
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites.items():
        if only and name not in only:
            continue
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suites": sorted((only or set(suites)) & set(suites)),
                       "failed": failed,
                       "results": common.RESULTS}, f, indent=2)
        print(f"wrote {len(common.RESULTS)} rows to {args.json}",
              file=sys.stderr)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()

"""Capacity sweep: static configurations vs the adaptive controller under
phase-shifting load, priced through the paper's deployment costs.

The paper's Tables 2–3 argument is that deployment economics hinge on the
host/accelerator *balance*: an imbalanced box (weak host, strong FPGA)
wastes the accelerator and can cost more per query than the CPU baseline.
PR 2 reproduced the imbalance plateau; this harness closes the loop with
the capacity subsystem (``repro.capacity``):

For each simulated box shape (``SIM_PROFILES``: ``weak_host`` = the
f1.2xlarge-style 8-vCPU host, ``balanced`` = the c5.12xlarge-style
48-vCPU host), drive the same phase-shifting open-loop load
(``PhasedOpenLoopGen``) through

- a **static grid** of hand-picked batch-bucket targets at the full
  replica count — the best point is the hand-tuned optimum an operator
  would converge to offline, and
- one **controlled** run starting from the *worst* static configuration
  with the :class:`~repro.capacity.CapacityController` attached — no
  manual retuning.

Recorded per config: the controller's recovered fraction of the
hand-tuned optimum throughput (acceptance bar: >= 0.8 on both box
shapes), its bottleneck diagnosis history, and a
:class:`~repro.capacity.CostReport` row per configuration — measured
throughput priced to $/1k-queries, where the controlled run is charged
only for its time-weighted mean *active* replicas (a parked replica can
be reassigned or powered down). The ``capacity`` section of
``BENCH_endtoend.json`` carries all of it.

Run directly (``--smoke`` shrinks the load for CI):

    PYTHONPATH=src python benchmarks/fig14_capacity.py [--smoke]
"""
import time

try:
    from benchmarks.common import emit
except ModuleNotFoundError:     # run as a file: benchmarks/fig14_capacity.py
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.common import emit

# static grid of batch-bucket targets (the operator's hand-tuning axis);
# the controlled run starts from the first (worst) entry
BATCH_GRID = (4, 8, 16, 32)
REPLICAS = 4
MAX_QUEUE = 64

# phase-shifting offered load per box shape: (duration_s, qps) — a ramp
# the static points can't follow and the controller must re-diagnose
PHASES = {
    "weak_host": [(0.6, 800.0), (1.2, 2400.0), (0.6, 1600.0)],
    "balanced": [(0.6, 1000.0), (1.2, 3000.0), (0.6, 2000.0)],
}

# structured points for the BENCH_endtoend.json "capacity" section
CAPACITY_POINTS = []


def _session(profile, *, target_batch, capacity=None):
    from repro.serve import ServeConfig, SimServer, build
    cfg = ServeConfig(
        replicas=REPLICAS, routing="least_loaded",
        target_batch=target_batch, deadline=0.01,
        max_queue=MAX_QUEUE, policy="shed_oldest",
        capacity=capacity,
        server_factory=lambda i: SimServer.from_profile(profile))
    return build(cfg).session()


def _drive(sched, gen):
    """Drive the phased load, drain, return (qps, completions, report)."""
    t0 = time.perf_counter()
    gen.drive(sched)
    outs = sched.result()
    dt = time.perf_counter() - t0
    rep = sched.report(offered_qps=gen.mean_qps)
    return len(outs) / dt, outs, rep


def capacity_sweep(profiles=("weak_host", "balanced"), *, smoke=False):
    from repro.capacity import CapacityConfig, CostReport
    from repro.serve import PhasedOpenLoopGen, SyntheticWorkload

    scale = 0.25 if smoke else 1.0
    grid = (BATCH_GRID[0], BATCH_GRID[-1]) if smoke else BATCH_GRID
    report = CostReport()
    for profile in profiles:
        phases = [(d * scale, q) for d, q in PHASES[profile]]
        workload = SyntheticWorkload(prompt_len=8, max_new_tokens=4, seed=3)

        # hand-tuned optimum: best static batch target at full replicas
        static = {}
        for tb in grid:
            gen = PhasedOpenLoopGen(workload, phases, seed=14)
            qps, _, _ = _drive(_session(profile, target_batch=tb), gen)
            static[tb] = qps
        best_tb = max(static, key=static.get)
        best_qps = static[best_tb]

        # controlled: start from the WORST static point, let the
        # controller re-balance online (no manual retuning)
        cap = CapacityConfig(window_s=0.05 if smoke else 0.1, confirm=2,
                             min_batch=grid[0], max_batch=grid[-1],
                             min_queue=16, max_queue=256)
        gen = PhasedOpenLoopGen(workload, phases, seed=14)
        ctl_qps, _, rep = _drive(
            _session(profile, target_batch=grid[0], capacity=cap), gen)
        recovered = ctl_qps / best_qps if best_qps > 0 else 0.0
        mean_active = float(rep.capacity.get("mean_active_replicas",
                                             REPLICAS))

        # price the measured numbers through the paper's unit costs: the
        # static optimum pays for all replicas all the time, the
        # controlled run only for its mean active set
        srow = report.add(f"{profile}/static_tb{best_tb}", host=profile,
                          replicas=REPLICAS, achieved_qps=best_qps)
        crow = report.add(f"{profile}/controlled", host=profile,
                          replicas=mean_active, achieved_qps=ctl_qps)
        point = {
            "profile": profile,
            "phases": phases,
            "static_qps_by_batch": {str(k): v for k, v in static.items()},
            "best_static_batch": best_tb,
            "best_static_qps": best_qps,
            "controlled_qps": ctl_qps,
            "recovered_fraction": recovered,
            "diagnosis": rep.capacity.get("diagnosis"),
            "diagnosis_history": rep.capacity.get("history", []),
            "n_controller_actions": rep.capacity.get("n_actions", 0),
            "final_knobs": rep.capacity.get("final", {}),
            "mean_active_replicas": mean_active,
            "static_usd_per_1k": srow.usd_per_1k,
            "controlled_usd_per_1k": crow.usd_per_1k,
        }
        CAPACITY_POINTS.append(point)
        emit(f"fig14_{profile}_static", 1e6 / max(best_qps, 1e-9),
             f"best_tb={best_tb} qps={best_qps:.0f} "
             f"${srow.usd_per_1k:.5f}/1k", **{
                 k: point[k] for k in ("profile", "best_static_batch",
                                       "best_static_qps",
                                       "static_qps_by_batch",
                                       "static_usd_per_1k")})
        emit(f"fig14_{profile}_controlled", 1e6 / max(ctl_qps, 1e-9),
             f"qps={ctl_qps:.0f} recovered={recovered:.2f} "
             f"diag={point['diagnosis']} "
             f"active={mean_active:.2f}/{REPLICAS} "
             f"${crow.usd_per_1k:.5f}/1k", **point)
    CAPACITY_POINTS.append({"cost_report": report.as_dict()})
    return report


def run():
    capacity_sweep()


if __name__ == "__main__":
    import argparse
    import json

    from benchmarks import common

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="shrunk load (CI): shorter phases, 2-point grid")
    ap.add_argument("--profiles", nargs="+", default=None,
                    metavar="NAME", help="box shapes to sweep "
                    "(default: weak_host balanced)")
    ap.add_argument("--json", nargs="?", const="BENCH_endtoend.json",
                    default="BENCH_endtoend.json", metavar="PATH",
                    help="merge structured results into PATH (default: "
                         "BENCH_endtoend.json)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    capacity_sweep(tuple(args.profiles) if args.profiles
                   else ("weak_host", "balanced"), smoke=args.smoke)
    payload = {"suites": ["fig14"], "failed": [],
               "results": common.RESULTS, "capacity": CAPACITY_POINTS}
    try:
        # merge into an existing run (CI writes fig13's sweeps first,
        # then adds the capacity section on top)
        with open(args.json) as f:
            prev = json.load(f)
        payload["suites"] = sorted(set(prev.get("suites", [])) | {"fig14"})
        payload["failed"] = prev.get("failed", [])
        payload["results"] = prev.get("results", []) + common.RESULTS
        payload["capacity"] = prev.get("capacity", []) + CAPACITY_POINTS
        for key, val in prev.items():
            # sections other harnesses wrote (cache, trace, ...)
            payload.setdefault(key, val)
    except (OSError, ValueError):
        pass
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)

"""Async submission pipeline: backpressure bounds, open/closed-loop batch
formation, async-vs-sync bit-identity, multi-device round-robin."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.serve import (AsyncScheduler, ClosedLoopGen, EngineGroup,
                         LMServer, MetricsCollector, OpenLoopGen,
                         SchedulerConfig, SyntheticWorkload,
                         form_batch_groups, poisson_arrivals)


def run_sync(server, reqs, *, target_batch, deadline):
    """Synchronous baseline: form batches with the paper's deadline policy,
    then run them one at a time (the device idles during host encode)."""
    out = []
    for rs in form_batch_groups(reqs, target_batch=target_batch,
                                deadline=deadline):
        out.extend(server.generate_batch(rs))
    return out


def run_pipe(server, reqs, *, target_batch, deadline, devices=None,
             metrics=None):
    """Pipelined replay of the same batch groups through EngineGroup —
    the implementation behind ``Server.serve(mode="pipelined")``."""
    groups = form_batch_groups(reqs, target_batch=target_batch,
                               deadline=deadline)
    group = EngineGroup.from_server(server, devices=devices)
    return group.run_groups(groups, metrics=metrics)


@pytest.fixture(scope="module")
def server():
    cfg = get_config("llama3.2-3b").reduced()
    return LMServer(cfg, max_seq=48)


@pytest.fixture(scope="module")
def workload(server):
    return SyntheticWorkload(vocab=server.cfg.vocab, prompt_len=6,
                             max_new_tokens=3, seed=1)


def test_poisson_arrivals_seeded():
    a = poisson_arrivals(64, 100.0, seed=5)
    b = poisson_arrivals(64, 100.0, seed=5)
    np.testing.assert_array_equal(a, b)
    assert np.all(np.diff(a) > 0)
    # mean inter-arrival ~ 1/qps
    assert 0.5 / 100.0 < np.diff(a).mean() < 2.0 / 100.0


def test_async_identical_to_sync_baseline(server, workload):
    """(c) The pipelined path must be bit-identical to the synchronous
    baseline for the same request stream."""
    reqs = OpenLoopGen(workload, qps=200.0, n=12, seed=7).requests()
    sync = run_sync(server, reqs, target_batch=4, deadline=0.01)
    pipe = run_pipe(server, reqs, target_batch=4, deadline=0.01)
    assert len(sync) == len(pipe) == 12
    by_sync = {c.rid: c for c in sync}
    for c in pipe:
        ref = by_sync[c.rid]
        np.testing.assert_array_equal(ref.tokens, c.tokens)
        assert ref.batch_size == c.batch_size
        assert ref.truncated == c.truncated


def test_backpressure_bounds_queue_under_overload(server, workload):
    """(a) Under a 4x-overload burst the bounded queue never exceeds its
    configured depth, rejections happen, and the report carries the
    device-idle-fraction signal."""
    max_queue = 8
    sched = AsyncScheduler(server, target_batch=4, deadline=0.002,
                           max_queue=max_queue, policy="reject")
    reqs = workload.build(4 * max_queue)
    accepted = sum(sched.submit(r) for r in reqs)
    outs = sched.result()
    rep = sched.report(offered_qps=1000.0)
    assert rep.max_queue_depth <= max_queue
    assert sched.n_rejected > 0
    assert accepted + sched.n_rejected == 4 * max_queue
    assert len(outs) == accepted
    assert 0.0 <= rep.device_idle_fraction <= 1.0
    assert rep.breakdown["device"].n == accepted


def test_shed_oldest_policy_bounds_queue(server, workload):
    sched = AsyncScheduler(server, target_batch=4, deadline=0.002,
                           max_queue=8, policy="shed_oldest")
    reqs = workload.build(32, rid_base=100)
    for r in reqs:
        assert sched.submit(r)       # shed admits by evicting, never refuses
    outs = sched.result()
    rep = sched.report()
    assert rep.max_queue_depth <= 8
    assert sched.n_shed + len(outs) == 32


def test_open_loop_low_qps_small_batches(server, workload):
    """(b1) Open loop far below capacity: deadline flushes dominate, so
    batches stay well under target size (logical-time replay)."""
    gen = OpenLoopGen(workload, qps=10.0, n=12, seed=3)
    reqs = gen.requests()   # mean gap 100 ms >> 5 ms deadline
    outs = run_pipe(server, reqs, target_batch=8, deadline=0.005)
    assert len(outs) == 12
    assert max(o.batch_size for o in outs) <= 2


def test_closed_loop_fills_target_batches(server, workload):
    """(b2) Closed loop with concurrency >= target: every batch forms at
    exactly target size."""
    sched = AsyncScheduler(server, target_batch=4, deadline=5.0,
                           max_queue=32, policy="block")
    ClosedLoopGen(workload, concurrency=8, n=16).drive(sched)
    outs = sched.result()
    assert len(outs) == 16
    assert all(o.batch_size == 4 for o in outs)


def test_scheduler_tokens_match_solo_generation(server, workload):
    """Live scheduling must not change results: batching is composition-
    independent (masked attention), so tokens equal solo generation even
    though live batch composition is timing-dependent."""
    reqs = workload.build(8, rid_base=200)
    solo = {r.rid: server.generate_batch([r])[0].tokens for r in reqs}
    sched = AsyncScheduler(server, target_batch=4, deadline=0.005,
                           max_queue=32, policy="block")
    for r in reqs:
        sched.submit(r)
    outs = sched.result()
    assert sorted(c.rid for c in outs) == sorted(solo)
    for c in outs:
        np.testing.assert_array_equal(solo[c.rid], c.tokens)


def test_metrics_breakdown_complete(server, workload):
    metrics = MetricsCollector()
    reqs = OpenLoopGen(workload, qps=500.0, n=8, seed=11).requests()
    run_pipe(server, reqs, target_batch=4, deadline=0.01, metrics=metrics)
    rep = metrics.report(offered_qps=500.0)
    assert rep.n_completed == 8
    for part in ("encode", "device", "total"):
        assert rep.breakdown[part].n == 8
        assert rep.breakdown[part].p50_ms >= 0.0
    assert rep.achieved_qps > 0.0
    d = rep.as_dict()
    assert set(d["breakdown"]) == {"queue_wait", "encode", "device",
                                   "drain", "total"}


def test_scheduler_config_validation():
    with pytest.raises(ValueError):
        SchedulerConfig(policy="drop_everything")


def test_device_error_surfaces_instead_of_hanging(server, workload):
    """A request whose prompt exceeds max_seq kills the device stage; the
    error must propagate out of result(), not wedge producers on the full
    handoff queue."""
    sched = AsyncScheduler(server, target_batch=1, deadline=0.001,
                           max_queue=16, policy="block")
    bad = workload.build(1, rid_base=300)[0]
    bad.tokens = np.ones(server.max_seq + 4, np.int32)   # oversized prompt
    sched.submit(bad)
    for r in workload.build(6, rid_base=310):
        try:
            sched.submit(r)
        except RuntimeError:
            break                    # batcher already saw the worker die
    with pytest.raises(RuntimeError):
        sched.result()


def test_result_without_submissions_returns_empty(server):
    sched = AsyncScheduler(server, target_batch=4, deadline=0.01,
                           max_queue=8)
    assert sched.result() == []


def test_blocked_submitter_fails_fast_on_pipeline_death(server, workload):
    """policy='block' must not wedge forever when the pipeline dies: the
    waiter wakes and raises instead of waiting for space that will never
    free up."""
    sched = AsyncScheduler(server, target_batch=1, deadline=0.001,
                           max_queue=2, policy="block")
    bad = workload.build(1, rid_base=400)[0]
    bad.tokens = np.ones(server.max_seq + 4, np.int32)   # kills the worker
    sched.submit(bad)
    with pytest.raises(RuntimeError):
        for r in workload.build(8, rid_base=410):
            sched.submit(r)          # must raise, not hang
    with pytest.raises(RuntimeError):
        sched.result()


def test_closed_loop_survives_rejections(server, workload):
    """Rejected/never-completing requests must return their concurrency
    permit — the drive loop may not wedge under backpressure."""
    sched = AsyncScheduler(server, target_batch=2, deadline=0.001,
                           max_queue=2, policy="reject")
    gen = ClosedLoopGen(workload, concurrency=4, n=12, seed=9)
    accepted = gen.drive(sched)      # would deadlock on permit leaks
    outs = sched.result()
    assert len(outs) == accepted
    assert accepted + sched.n_rejected == 12


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >=2 devices "
                           "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
def test_multi_device_round_robin_identical(server, workload):
    """CI matrix job: batches round-robin across host devices and still
    produce bit-identical completions."""
    reqs = OpenLoopGen(workload, qps=200.0, n=10, seed=7).requests()
    sync = run_sync(server, reqs, target_batch=4, deadline=0.01)
    multi = run_pipe(server, reqs, target_batch=4, deadline=0.01,
                     devices=jax.devices())
    by_sync = {c.rid: c for c in sync}
    for c in multi:
        np.testing.assert_array_equal(by_sync[c.rid].tokens, c.tokens)

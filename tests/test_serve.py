"""Serving: batched generation determinism, continuous batching stream,
MCT rule-filter stage integration."""
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.compiler import compile_rules
from repro.core.engine import ErbiumEngine
from repro.core.rules import generate_queries, generate_rules
from repro.serve.engine import LMServer, Request


@pytest.fixture(scope="module")
def server():
    cfg = get_config("llama3.2-3b").reduced()
    return LMServer(cfg, max_seq=48)


def test_generate_batch_greedy_deterministic(server):
    prompt = np.asarray([3, 5, 7, 11], np.int32)
    reqs = [Request(rid=0, tokens=prompt, max_new_tokens=6),
            Request(rid=1, tokens=prompt, max_new_tokens=6)]
    outs = server.generate_batch(reqs)
    np.testing.assert_array_equal(outs[0].tokens, outs[1].tokens)
    assert len(outs[0].tokens) == 6


def test_batch_independence(server):
    """A request's output must not depend on its batch neighbours."""
    p0 = np.asarray([3, 5, 7, 11], np.int32)
    p1 = np.asarray([2, 4, 6, 8], np.int32)
    solo = server.generate_batch([Request(rid=0, tokens=p0,
                                          max_new_tokens=5)])[0]
    pair = server.generate_batch([
        Request(rid=0, tokens=p0, max_new_tokens=5),
        Request(rid=1, tokens=p1, max_new_tokens=5)])[0]
    np.testing.assert_array_equal(solo.tokens, pair.tokens)


def test_form_batches_by_deadline(server):
    reqs = [Request(rid=i, tokens=np.asarray([1 + i, 2, 3], np.int32),
                    max_new_tokens=3, arrival=i * 0.001) for i in range(6)]
    outs = [c for rs in server.form_batches(reqs, target_batch=4,
                                            deadline=0.01)
            for c in server.generate_batch(rs)]
    assert len(outs) == 6
    sizes = sorted({o.batch_size for o in outs})
    assert sizes == [2, 4]          # one full batch + one deadline flush


def test_context_limit_sets_truncated_flag():
    """Mixed prompt lengths hitting max_seq: generation stops at the
    context limit but emits the generated-so-far tokens with an explicit
    ``truncated`` flag instead of silently shortening the output."""
    cfg = get_config("llama3.2-3b").reduced()
    srv = LMServer(cfg, max_seq=8)
    wants_more = Request(rid=0, tokens=np.asarray([1, 2, 3, 4], np.int32),
                         max_new_tokens=10)
    fits = Request(rid=1, tokens=np.asarray([5, 6], np.int32),
                   max_new_tokens=2)
    outs = {c.rid: c for c in srv.generate_batch([wants_more, fits])}
    assert outs[0].truncated
    assert 0 < len(outs[0].tokens) < 10
    assert not outs[1].truncated
    assert len(outs[1].tokens) == 2


def test_rule_filter_drops_infeasible():
    cfg = get_config("llama3.2-3b").reduced()
    rs = generate_rules(150, version=2, seed=3)
    table = compile_rules(rs)
    eng = ErbiumEngine(table, backend="ref")
    srv = LMServer(cfg, max_seq=32, rule_filter=eng)
    qs = generate_queries(rs, 4, seed=5, match_bias=1.0)
    # find the actual decisions to build one feasible, one infeasible request
    dec, _, _ = eng.match_queries(qs)
    dec = np.asarray(dec)
    mct0 = int(dec[0]) if dec[0] >= 0 else table.default_decision
    good = Request(rid=0, tokens=np.asarray([1, 2], np.int32),
                   max_new_tokens=2, mct_queries=[qs[0]],
                   connect_minutes=[mct0 + 30])
    bad = Request(rid=1, tokens=np.asarray([1, 2], np.int32),
                  max_new_tokens=2, mct_queries=[qs[1]],
                  connect_minutes=[0])
    outs = srv.generate_batch([good, bad])
    assert [o.rid for o in outs] == [0]

    # same pair through the live async scheduler: the filtered request
    # produces no Completion but must signal on_drop (closed-loop permit
    # accounting depends on it)
    from repro.serve import AsyncScheduler
    dropped = []
    sched = AsyncScheduler(srv, target_batch=2, deadline=0.1, max_queue=8)
    sched.on_drop = dropped.append
    sched.submit(good)
    sched.submit(bad)
    outs = sched.result()
    assert [o.rid for o in outs] == [0]
    assert dropped == [1]

"""MoE: shard_map EP/TP paths vs dense reference (single-device mesh —
the collective code path with tp=1 groups)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import moe as moe_mod


@pytest.mark.parametrize("mode", ["ep", "tp"])
def test_moe_forward_matches_ref(mode):
    cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=16,
                    capacity_factor=4.0, parallel_mode=mode)
    D = 8
    params = moe_mod.init_moe(jax.random.PRNGKey(0), D, cfg, "swiglu",
                              jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 6, D)), jnp.float32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    out = moe_mod.moe_forward(params, x, cfg=cfg, act="swiglu", mesh=mesh,
                              batch_axes=("data",))
    ref = moe_mod.moe_ref(params, x, cfg=cfg, act="swiglu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens_gracefully():
    cfg = MoEConfig(num_experts=2, top_k=1, d_ff_expert=8,
                    capacity_factor=0.26, parallel_mode="ep")
    D = 4
    params = moe_mod.init_moe(jax.random.PRNGKey(1), D, cfg, "gelu",
                              jnp.float32)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 32, D)), jnp.float32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    out = moe_mod.moe_forward(params, x, cfg=cfg, act="gelu", mesh=mesh,
                              batch_axes=("data",))
    assert bool(jnp.isfinite(out).all())
    # with tight capacity some token outputs are zero (dropped)
    norms = jnp.linalg.norm(out.reshape(-1, D), axis=-1)
    assert float((norms == 0).mean()) > 0.1


def test_capacity_formula():
    from repro.models.moe import capacity_for
    assert capacity_for(65536, 128, 8, 1.25) == 640
    assert capacity_for(8, 128, 8, 1.25) >= 1

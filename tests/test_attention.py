"""Blockwise attention vs naive softmax oracle: causal / window /
bidirectional / GQA / offsets; hypothesis shape sweep."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

pytest.importorskip(
    "hypothesis",
    reason="property tests need the 'test' extra (pip install -e .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.attention import (attention_scores_decode,
                                    blockwise_attention)


def naive_attention(q, k, v, causal, window, q_offset=0):
    B, Sq, K, G, d = q.shape
    Skv = k.shape[1]
    s = np.einsum("bqkgd,bskd->bkgqs", np.asarray(q, np.float64),
                  np.asarray(k, np.float64)) / np.sqrt(d)
    qpos = np.arange(Sq)[:, None] + q_offset
    kpos = np.arange(Skv)[None, :]
    mask = np.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = np.where(mask[None, None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("bkgqs,bskd->bqkgd", p, np.asarray(v, np.float64))


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 7),
                                           (False, 0), (True, 16)])
def test_blockwise_matches_naive(causal, window):
    rng = np.random.default_rng(3)
    B, S, K, G, d = 2, 33, 2, 3, 8
    q = jnp.asarray(rng.standard_normal((B, S, K, G, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, d)), jnp.float32)
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              block_q=8, block_kv=8)
    exp = naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), exp, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 40), st.integers(1, 3), st.integers(1, 2),
       st.integers(4, 16), st.booleans(), st.integers(0, 12),
       st.integers(0, 2**31 - 1))
def test_blockwise_property(S, K, G, bq, causal, window, seed):
    rng = np.random.default_rng(seed)
    B, d = 1, 4
    q = jnp.asarray(rng.standard_normal((B, S, K, G, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, d)), jnp.float32)
    if not causal and window > 0:
        window = 0
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              block_q=bq, block_kv=bq)
    exp = naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), exp, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("causal,window,S", [
    (True, 0, 33), (True, 7, 40), (False, 0, 24), (True, 12, 64)])
def test_qblock_matches_naive(causal, window, S):
    from repro.models.attention import qblock_attention
    rng = np.random.default_rng(S + window)
    B, K, G, d = 2, 2, 3, 8
    q = jnp.asarray(rng.standard_normal((B, S, K, G, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, d)), jnp.float32)
    out = qblock_attention(q, k, v, causal=causal, window=window,
                           block_q=8, block_kv=8)
    exp = naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), exp, rtol=3e-4, atol=3e-4)


def test_decode_matches_naive_last_row():
    rng = np.random.default_rng(5)
    B, S, K, G, d = 2, 17, 2, 2, 8
    q_all = jnp.asarray(rng.standard_normal((B, S, K, G, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, d)), jnp.float32)
    out = attention_scores_decode(q_all[:, -1:], k, v, pos=S, window=5)
    exp = naive_attention(q_all[:, -1:], k, v, True, 5, q_offset=S - 1)
    np.testing.assert_allclose(np.asarray(out), exp, rtol=2e-4, atol=2e-4)

"""Train loop: loss goes down, checkpoint resume is exact, microbatch
equivalence, gradient compression properties."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.train import grad_compress
from repro.train.loop import TrainConfig, fit
from repro.train.optimizer import AdamW


def test_loss_decreases():
    cfg = get_config("gemma3-1b").reduced()
    tc = TrainConfig(steps=25, batch=4, seq_len=32, lr=3e-3, warmup=5,
                     log_every=100)
    res = fit(cfg, tc, log=lambda s: None)
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first - 0.1, (first, last)


def test_resume_matches_uninterrupted(tmp_path):
    cfg = get_config("llama3.2-3b").reduced()
    common = dict(batch=4, seq_len=16, lr=1e-3, warmup=2, log_every=100,
                  schedule_steps=10)  # identical LR schedule on both legs
    # uninterrupted 10 steps
    res_a = fit(cfg, TrainConfig(steps=10, **common), log=lambda s: None)
    # 5 steps + resume for 5 more
    d = str(tmp_path / "ck")
    fit(cfg, TrainConfig(steps=5, ckpt_dir=d, ckpt_every=100, **common),
        log=lambda s: None)
    res_b = fit(cfg, TrainConfig(steps=10, ckpt_dir=d, ckpt_every=100,
                                 **common), log=lambda s: None)
    np.testing.assert_allclose(res_a.losses[5:], res_b.losses, rtol=1e-4)


def test_microbatch_equivalence():
    """M=1 vs M=4 gradient accumulation gives (near-)identical losses."""
    cfg = get_config("gemma3-1b").reduced()
    common = dict(steps=4, batch=8, seq_len=16, lr=1e-3, warmup=1,
                  log_every=100)
    r1 = fit(cfg, TrainConfig(microbatches=1, **common), log=lambda s: None)
    r4 = fit(cfg, TrainConfig(microbatches=4, **common), log=lambda s: None)
    # first-step loss: identical data, different averaging order
    assert abs(r1.losses[0] - r4.losses[0]) < 5e-2
    assert abs(r1.losses[-1] - r4.losses[-1]) < 1e-1


def test_grad_compress_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    st = grad_compress.init(g)
    q, s, st2 = grad_compress.compress(g, st)
    back = grad_compress.decompress(q, s)
    # quantisation error bounded by scale/2 per element
    err = np.abs(np.asarray(back["w"] - g["w"]))
    assert err.max() <= float(s["w"]) * 0.51
    # error feedback: residual equals the quantisation error
    np.testing.assert_allclose(np.asarray(st2.residual["w"]),
                               np.asarray(g["w"] - back["w"]), atol=1e-6)
    # second round with zero grads flushes the residual
    q2, s2, _ = grad_compress.compress(
        {"w": jnp.zeros_like(g["w"])}, st2)
    back2 = grad_compress.decompress(q2, s2)
    assert np.abs(np.asarray(back2["w"]) -
                  np.asarray(st2.residual["w"])).max() < float(s2["w"])


def test_grad_compress_int8_payload():
    g = {"w": jnp.ones((8, 8), jnp.float32)}
    q, s, _ = grad_compress.compress(g, grad_compress.init(g))
    assert q["w"].dtype == jnp.int8


def test_optimizer_state_dtype():
    opt = AdamW(state_dtype=jnp.bfloat16)
    p = {"w": jnp.ones((4, 4), jnp.float32)}
    st = opt.init(p)
    assert st.mu["w"].dtype == jnp.bfloat16

"""Pallas rule-match kernel vs pure-jnp oracle: shape/dtype sweeps +
hypothesis property tests (interpret mode executes the kernel body on CPU).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

pytest.importorskip(
    "hypothesis",
    reason="property tests need the 'test' extra (pip install -e .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.ops import _pad_to, device_table, match_rules
from repro.kernels.ref import rule_match_ref
from repro.kernels.rule_match import rule_match_pallas


def _random_tables(rng, B, R, C, weight_max=100):
    q = rng.integers(0, 50, (B, C)).astype(np.int32)
    mins = rng.integers(0, 50, (R, C)).astype(np.int32)
    widths = rng.integers(0, 30, (R, C)).astype(np.int32)
    maxs = mins + widths
    wild = rng.random((R, C)) < 0.5
    mins = np.where(wild, 0, mins).astype(np.int32)
    maxs = np.where(wild, np.iinfo(np.int32).max - 1, maxs).astype(np.int32)
    w = rng.integers(0, weight_max, (R,)).astype(np.int32)
    return q, mins, maxs, w


@pytest.mark.parametrize("B,R,C,tb,tr", [
    (64, 128, 8, 64, 128),
    (128, 256, 26, 64, 128),
    (256, 512, 31, 256, 512),
    (32, 512, 3, 32, 256),
    (512, 128, 13, 128, 128),
])
def test_kernel_matches_ref_shapes(B, R, C, tb, tr):
    rng = np.random.default_rng(B + R + C)
    q, mins, maxs, w = _random_tables(rng, B, R, C)
    bw, bi = rule_match_pallas(jnp.asarray(q.T), jnp.asarray(mins.T),
                               jnp.asarray(maxs.T), jnp.asarray(w[None]),
                               tile_b=tb, tile_r=tr, interpret=True)
    rw, ri = rule_match_ref(jnp.asarray(q), jnp.asarray(mins),
                            jnp.asarray(maxs), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(bw[0]), np.asarray(rw))
    np.testing.assert_array_equal(np.asarray(bi[0]), np.asarray(ri))


def test_tie_break_lowest_rule_index():
    # two identical rules with equal weight: index 0 must win, in-tile and
    # across tiles
    C = 4
    q = np.zeros((8, C), np.int32)
    mins = np.zeros((256, C), np.int32)
    maxs = np.full((256, C), 10, np.int32)
    w = np.full((256,), 7, np.int32)
    bw, bi = rule_match_pallas(jnp.asarray(q.T), jnp.asarray(mins.T),
                               jnp.asarray(maxs.T), jnp.asarray(w[None]),
                               tile_b=8, tile_r=64, interpret=True)
    assert (np.asarray(bi[0]) == 0).all()
    assert (np.asarray(bw[0]) == 7).all()


def test_no_match_returns_minus_one():
    C = 3
    q = np.full((16, C), 100, np.int32)
    mins = np.zeros((64, C), np.int32)
    maxs = np.full((64, C), 5, np.int32)
    w = np.full((64,), 3, np.int32)
    bw, bi = rule_match_pallas(jnp.asarray(q.T), jnp.asarray(mins.T),
                               jnp.asarray(maxs.T), jnp.asarray(w[None]),
                               tile_b=16, tile_r=64, interpret=True)
    assert (np.asarray(bw[0]) == -1).all()
    assert (np.asarray(bi[0]) == -1).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 97), st.integers(1, 130), st.integers(1, 12),
       st.integers(0, 2**31 - 1))
def test_property_match_semantics(B, R, C, seed):
    """For random tables, the op (with padding) equals brute force numpy."""
    rng = np.random.default_rng(seed)
    q, mins, maxs, w = _random_tables(rng, B, R, C)
    from repro.core.compiler import CompiledRuleTable  # noqa: F401
    ok = (q[:, None, :] >= mins[None]) & (q[:, None, :] <= maxs[None])
    matched = ok.all(-1)
    score = np.where(matched, w[None, :], -1)
    exp_w = score.max(1)
    exp_i = np.where(exp_w >= 0, score.argmax(1), -1)

    qp = _pad_to(jnp.asarray(q.T), 32, 1, 0)
    mp = _pad_to(jnp.asarray(mins.T), 64, 1, 1)
    xp = _pad_to(jnp.asarray(maxs.T), 64, 1, 0)
    wp = _pad_to(jnp.asarray(w[None]), 64, 1, -1)
    bw, bi = rule_match_pallas(qp, mp, xp, wp, tile_b=32, tile_r=64,
                               interpret=True)
    np.testing.assert_array_equal(np.asarray(bw[0])[:B], exp_w)
    np.testing.assert_array_equal(np.asarray(bi[0])[:B], exp_i)


@pytest.mark.parametrize("n_engines", [1, 2, 4])
def test_engine_lanes_equivalent(n_engines):
    from repro.core.compiler import compile_rules
    from repro.core.rules import generate_queries, generate_rules
    from repro.core.encoder import encode_queries

    rs = generate_rules(200, version=1, seed=9)
    t = compile_rules(rs)
    qs = generate_queries(rs, 128, seed=4)
    enc = jnp.asarray(encode_queries(t, qs))
    dt = device_table(t, tile_r=128)
    d1, w1, r1 = match_rules(enc, dt, tile_b=32, tile_r=128, n_engines=1)
    dn, wn, rn = match_rules(enc, dt, tile_b=32, tile_r=128,
                             n_engines=n_engines)
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(wn))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(dn))

"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, shape + finiteness asserts; decode consistency; full-config parameter
counts near the nominal sizes."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ASSIGNED_ARCHS, get_config
from repro.models.registry import build_model, make_inputs
from repro.train.optimizer import AdamW

NOMINAL = {
    "grok-1-314b": 314e9, "qwen3-moe-235b-a22b": 235e9,
    "xlstm-1.3b": 1.3e9, "llama-3.2-vision-11b": 11e9,
    "hubert-xlarge": 1.0e9, "llama3.2-3b": 3.2e9,
    "internlm2-20b": 20e9, "gemma3-1b": 1.0e9,
    "nemotron-4-340b": 340e9, "hymba-1.5b": 1.5e9,
}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = make_inputs(cfg, B, S, rng=np.random.default_rng(0))
    logits = model.logits(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    opt = AdamW(lr=1e-3, warmup=1, total_steps=10)
    ostate = opt.init(params)

    def loss_fn(p):
        return model.loss(p, batch)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    new_p, new_s, gnorm = opt.update(g32, ostate, params)
    assert bool(jnp.isfinite(gnorm))
    loss2 = model.loss(new_p, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", ["llama3.2-3b", "gemma3-1b", "hymba-1.5b"])
def test_smoke_decode_consistency(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = make_inputs(cfg, B, S, rng=np.random.default_rng(1))
    full = model.logits(params, batch).astype(jnp.float32)
    cache = model.init_cache(B, S)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, batch["tokens"][:, t:t + 1],
                         jnp.int32(t))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_full_config_param_counts(arch):
    cfg = get_config(arch)
    n = cfg.n_params()
    nominal = NOMINAL[arch]
    assert 0.7 * nominal <= n <= 1.35 * nominal, \
        f"{arch}: {n/1e9:.1f}B vs nominal {nominal/1e9:.0f}B"
    assert cfg.n_active_params() <= n


def test_moe_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    a = cfg.n_active_params()
    assert 15e9 <= a <= 30e9, f"active {a/1e9:.1f}B vs nominal 22B"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_shape_cell_assignment(arch):
    cfg = get_config(arch)
    cells = {c.name for c in cfg.shape_cells()}
    assert "train_4k" in cells and "prefill_32k" in cells
    if cfg.encoder_only:
        assert "decode_32k" not in cells
    if not cfg.supports_long_context:
        assert "long_500k" not in cells
    skips = dict(cfg.skipped_cells())
    assert cells.isdisjoint(skips)

"""Dry-run flow on a shrunken fake fleet (subprocess so XLA device-count
forcing can't leak into other tests)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest


def _run(args, env_extra, cwd="/root/repo"):
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.update(env_extra)
    return subprocess.run([sys.executable, "-m", "repro.launch.dryrun",
                           *args], capture_output=True, text=True, cwd=cwd,
                          env=env, timeout=900)


@pytest.mark.slow
def test_dryrun_cell_small_mesh(tmp_path):
    out = _run(["--arch", "gemma3-1b", "--shape", "decode_32k",
                "--mesh", "single", "--out", str(tmp_path)],
               {"REPRO_DRYRUN_DEVICES": "4", "REPRO_DRYRUN_MESH": "2x2"})
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    rec = json.loads(
        (tmp_path / "gemma3-1b__decode_32k__single.json").read_text())
    assert rec["ok"]
    assert rec["hlo"]["flops"] > 0
    assert rec["hlo"]["num_partitions"] == 4
    assert rec["model_flops"] > 0


@pytest.mark.slow
def test_dryrun_multipod_small_mesh(tmp_path):
    out = _run(["--arch", "gemma3-1b", "--shape", "decode_32k",
                "--mesh", "multi", "--out", str(tmp_path)],
               {"REPRO_DRYRUN_DEVICES": "8", "REPRO_DRYRUN_MESH": "2x2x2"})
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    rec = json.loads(
        (tmp_path / "gemma3-1b__decode_32k__multi.json").read_text())
    assert rec["ok"]
    assert rec["hlo"]["num_partitions"] == 8

"""Offline compiler: v1/v2 lowering, dictionaries, overlap elimination,
partition tables."""
import numpy as np
import pytest

from repro.core.compiler import INT_MAX, compile_rules
from repro.core.encoder import encode_queries
from repro.core.rules import (WILDCARD, Rule, RuleSet, generate_queries,
                              generate_rules, schema_v2)
from repro.kernels.ref import rule_match_ref

import jax.numpy as jnp


def test_v1_v2_column_counts():
    t1 = compile_rules(generate_rules(50, version=1, seed=0))
    t2 = compile_rules(generate_rules(50, version=2, seed=0))
    assert t1.n_cols == 22                      # ranges native
    assert t2.n_cols == 31                      # 21 cat + 5 ranges x 2
    assert t2.n_cols > t1.n_cols                # "bigger NFA" in v2


def test_wildcards_become_full_intervals():
    rs = generate_rules(50, version=1, seed=0)
    t = compile_rules(rs)
    # at least one wildcard entry spans the full interval
    assert (t.mins == 0).any() and (t.maxs == INT_MAX).any()


def _mk_ruleset(rules):
    return RuleSet(schema=schema_v2(), rules=rules, version=2)


def test_overlap_elimination_unique_match():
    """Two overlapping flight-number ranges (same other criteria) must be
    split so any flight number matches exactly one compiled rule."""
    base = {"airport": 1}
    r0 = Rule(values={**base, "arr_flightno": (100, 500)}, decision=30,
              rule_id=0)
    r1 = Rule(values={**base, "arr_flightno": (300, 800)}, decision=60,
              rule_id=1)
    t = compile_rules(_mk_ruleset([r0, r1]))
    cols = {c.name: j for j, c in enumerate(t.columns)}
    lo, hi = cols["arr_flightno.lo"], cols["arr_flightno.hi"]
    # compiled ranges must be pairwise disjoint
    ivs = sorted((t.mins[i, lo], t.maxs[i, hi]) for i in range(t.n_rules))
    for (a1, b1), (a2, b2) in zip(ivs, ivs[1:]):
        assert b1 < a2, f"overlap: {(a1, b1)} vs {(a2, b2)}"
    # narrow (more precise) rule wins in the overlap region
    winners = {}
    for fn in (150, 400, 700):
        cover = [i for i in range(t.n_rules)
                 if t.mins[i, lo] <= fn <= t.maxs[i, hi]]
        assert len(cover) == 1, f"flight {fn} covered by {cover}"
        winners[fn] = t.decisions[cover[0]]
    assert winners[150] == 30 and winners[700] == 60
    # overlap region goes to the more precise (narrower) source rule
    assert winners[400] == 30


def test_overlap_count_is_moderate():
    """Paper: zero to a few hundred extra rules among 160k (scaled here)."""
    rs = generate_rules(4_000, version=2, seed=5)
    t = compile_rules(rs)
    extra = t.n_rules - len(rs.rules)
    assert 0 <= extra <= len(rs.rules) * 0.05


def test_partition_table_covers_all_rules():
    rs = generate_rules(500, version=2, seed=1)
    t = compile_rules(rs)
    assert t.part_order.shape[0] == t.n_rules
    assert sorted(t.part_order.tolist()) == list(range(t.n_rules))
    # offsets monotone
    assert (np.diff(t.part_offsets) >= 0).all()
    assert t.part_offsets[-1] + len(t.wildcard_rows) == t.n_rules


def test_oov_query_values_only_match_wildcards():
    r0 = Rule(values={"airport": 1, "arr_terminal": 2}, decision=25)
    r1 = Rule(values={"airport": 1, "arr_terminal": WILDCARD}, decision=60)
    t = compile_rules(_mk_ruleset([r0, r1]))
    qs = generate_queries(_mk_ruleset([r0, r1]), 1, seed=0, match_bias=0.0)
    q = dict(qs[0])
    q["airport"] = 1
    q["arr_terminal"] = 999_999      # unseen raw value
    enc = encode_queries(t, [q])
    w, idx = rule_match_ref(jnp.asarray(enc), jnp.asarray(t.mins),
                            jnp.asarray(t.maxs), jnp.asarray(t.weights))
    matched = [i for i in [int(idx[0])] if i >= 0]
    for i in matched:
        assert t.maxs[i, [j for j, c in enumerate(t.columns)
                          if c.name == "arr_terminal"][0]] == INT_MAX

"""Fault tolerance: heartbeats, elastic remesh planning, straggler policy,
and an injected-failure restart through the train loop."""
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.ft.failures import (ElasticPlan, FailureInjector,
                               HeartbeatMonitor, StragglerPolicy,
                               plan_elastic_mesh)
from repro.ft.failures import HeartbeatMonitor
from repro.train.loop import TrainConfig, fit


def test_heartbeat_detection():
    hb = HeartbeatMonitor(timeout=5.0)
    hb.beat("h0", now=0.0)
    hb.beat("h1", now=0.0)
    hb.beat("h0", now=4.0)
    assert hb.failed(now=6.0) == ["h1"]
    assert hb.alive(now=6.0) == ["h0"]


def test_elastic_plan_preserves_model_parallelism():
    # 256 chips (16x16), lose 16 -> 240 survivors -> data=15
    p = plan_elastic_mesh(240, model_parallel=16, global_batch=256,
                          orig_data=16)
    assert p.model == 16 and p.data == 15
    assert p.n_devices == 240
    assert p.global_batch == 240  # 16 per replica x 15
    # atomic TP groups: 250 survivors still yield data=15
    p2 = plan_elastic_mesh(250, model_parallel=16, global_batch=256,
                           orig_data=16)
    assert p2.data == 15 and p2.dropped_devices == 10


def test_elastic_plan_raises_below_minimum():
    with pytest.raises(ValueError):
        plan_elastic_mesh(8, model_parallel=16, global_batch=64)


def test_straggler_policy_drops_and_rescales():
    sp = StragglerPolicy(tolerance=2.0)
    sp.observe(1.0)
    kept, scale = sp.commit([1.0, 1.1, 5.0, 0.9])
    assert 2 not in kept and len(kept) == 3
    assert scale == pytest.approx(4 / 3)


def test_straggler_all_late_keeps_fastest():
    sp = StragglerPolicy(tolerance=1.5)
    sp.observe(1.0)
    kept, scale = sp.commit([9.0, 5.0, 7.0])
    assert kept == [1]
    assert scale == 3.0


def test_injected_failure_restart(tmp_path):
    cfg = get_config("llama3.2-3b").reduced()
    tc = TrainConfig(steps=8, batch=4, seq_len=16, ckpt_dir=str(tmp_path),
                     ckpt_every=3, log_every=100, lr=1e-3)
    inj = FailureInjector(schedule={5: "host3"})
    res = fit(cfg, tc, injector=inj, log=lambda s: None)
    assert res.restarts == 1
    assert res.steps_done == 8
    assert all(np.isfinite(res.losses))

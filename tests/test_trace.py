"""End-to-end request tracing: ring buffer semantics, bit-identity of the
disabled default, exact reconciliation of TraceReport against RunReport,
cache/controller event wiring, exporters, and the serve() convenience."""
import json

import numpy as np
import pytest

from repro.serve import (CacheConfig, CapacityConfig, MetricsCollector,
                         ReplicaTraceStats, ServeConfig, SimServer, Span,
                         TraceConfig, TraceReport, Tracer, build, coerce,
                         render_timeline, serve, sim_requests)
from repro.serve.capacity import CapacityController
from repro.serve.trace import LIFECYCLE_STAGES, chrome_events

assert ReplicaTraceStats is not None      # part of the public surface


def fast_sim(i=0, **kw):
    """Millisecond-scale sim engine so traced runs stay fast."""
    kw.setdefault("host_ms_per_batch", 0.5)
    kw.setdefault("device_ms_per_batch", 1.0)
    return SimServer(**kw)


class FilteringSim(SimServer):
    """SimServer that drops every request whose first token is 7 —
    exercises the engine-drop path (drop marks, negative caching)."""

    def execute_prepared(self, pb, *, device=None):
        comps = super().execute_prepared(pb, device=device)
        doomed = {r.rid for r in pb.requests if int(r.tokens[0]) == 7}
        return [c for c in comps if c.rid not in doomed]


# ---------------------------------------------------------------------------
# shared config coercion (satellite: one rule for cache/capacity/trace)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls", [CacheConfig, CapacityConfig, TraceConfig])
def test_coerce_rule_uniform_across_subsystems(cls):
    assert cls.coerce(None) is None
    assert cls.coerce(False) is None
    assert isinstance(cls.coerce(True), cls)
    inst = cls()
    assert cls.coerce(inst) is inst
    assert isinstance(cls.coerce({}), cls)
    with pytest.raises(ValueError, match=cls.__name__):
        cls.coerce(42)


def test_coerce_dict_sets_knobs_and_names_field_in_error():
    assert coerce(TraceConfig, {"capacity": 16}).capacity == 16
    with pytest.raises(ValueError, match="trace"):
        coerce(TraceConfig, "yes")
    with pytest.raises(ValueError, match="snapshots"):
        coerce(TraceConfig, "yes", field="snapshots")


def test_configs_coerce_on_construction():
    cfg = ServeConfig(server_factory=fast_sim, trace=True,
                      cache={"coalesce": False})
    assert isinstance(cfg.trace, TraceConfig)
    assert isinstance(cfg.cache, CacheConfig) and not cfg.cache.coalesce
    sch = cfg.scheduler_config(trace={"capacity": 32})
    assert sch.trace.capacity == 32


# ---------------------------------------------------------------------------
# Tracer mechanics
# ---------------------------------------------------------------------------

def test_ring_buffer_bound_and_drop_accounting():
    tr = Tracer({"capacity": 4})
    for i in range(10):
        tr.mark("submit", float(i), rid=i)
    assert len(tr) == 4
    assert tr.n_emitted == 10
    assert tr.n_dropped == 6
    assert [s.rid for s in tr.spans()] == [6, 7, 8, 9]   # oldest evicted
    rep = tr.report()
    assert rep.n_dropped == 6 and rep.n_spans == 4
    tr.clear()
    assert len(tr) == 0 and tr.n_dropped == 0


def test_span_properties_and_json_safety():
    s = Span("device_execute", 1.0, 1.002, replica=np.int64(1),
             meta={"rids": [np.int64(3)], "cost": np.float64(0.5)})
    assert s.duration_ms == pytest.approx(2.0)
    assert not s.is_mark
    d = s.as_dict()
    assert type(d["replica"]) is int
    assert type(d["meta"]["rids"][0]) is int
    assert type(d["meta"]["cost"]) is float
    json.dumps(d)                               # nothing numpy leaks out
    m = Span("submit", 1.0, 1.0, rid=4)
    assert m.is_mark and m.as_dict() == {"stage": "submit", "t0": 1.0,
                                         "t1": 1.0, "rid": 4}


def test_tracer_off_by_default_everywhere():
    srv = build(ServeConfig(server_factory=fast_sim, target_batch=4,
                            deadline=0.01))
    assert srv.tracer is None
    assert srv.trace_report() is None
    with pytest.raises(RuntimeError, match="trace"):
        srv.export_trace("/tmp/never.json")
    sched = srv.session()
    assert sched.tracer is None
    assert sched.trace_report() is None
    sched.result()


# ---------------------------------------------------------------------------
# bit-identity: trace=None and trace=True produce identical completions
# ---------------------------------------------------------------------------

def test_trace_on_is_bit_identical_to_off():
    reqs = sim_requests(24, max_new_tokens=4)
    base_kw = dict(server_factory=fast_sim, replicas=2, routing="sticky",
                   target_batch=4, deadline=0.01)
    with build(ServeConfig(**base_kw)) as plain:
        ref = {c.rid: c for c in plain.serve(reqs, mode="pipelined")}
    with build(ServeConfig(trace=True, **base_kw)) as traced:
        outs = traced.serve(reqs, mode="pipelined")
        assert traced.tracer is not None and len(traced.tracer) > 0
    assert sorted(c.rid for c in outs) == sorted(ref)
    for c in outs:
        np.testing.assert_array_equal(ref[c.rid].tokens, c.tokens)
        assert ref[c.rid].batch_size == c.batch_size


# ---------------------------------------------------------------------------
# reconciliation: TraceReport vs RunReport on the same run
# ---------------------------------------------------------------------------

def assert_stats_match(trace_stats, run_stats):
    assert trace_stats.n == run_stats.n
    for f in ("mean_ms", "p50_ms", "p95_ms", "p99_ms", "max_ms"):
        assert getattr(trace_stats, f) == \
            pytest.approx(getattr(run_stats, f), rel=1e-9, abs=1e-12)


def reconcile(sched_report, trace_report):
    """The cross-check the module docstring promises: spans reuse the
    exact timestamps handed to MetricsCollector, so the two reports'
    per-stage stats agree to float roundoff."""
    assert trace_report.counts.get("complete", 0) == \
        sched_report.n_completed
    assert trace_report.counts.get("shed", 0) == sched_report.n_shed
    assert trace_report.counts.get("reject", 0) == sched_report.n_rejected
    assert_stats_match(trace_report.stages["queue_wait"],
                       sched_report.breakdown["queue_wait"])
    assert_stats_match(trace_report.stages["encode"],
                       sched_report.breakdown["encode"])
    assert_stats_match(trace_report.stages["device_execute"],
                       sched_report.breakdown["device"])
    assert_stats_match(trace_report.stages["total"],
                       sched_report.breakdown["total"])
    for r, rs in sched_report.per_replica.items():
        ts = trace_report.per_replica.get(r)
        if rs.n_batches:
            assert ts is not None
            assert ts.n_batches == rs.n_batches
            assert ts.n_dispatches == rs.n_batches
            assert ts.n_requests == rs.n_requests
            assert ts.busy_s == pytest.approx(rs.busy_s, rel=1e-9)


def test_live_session_trace_reconciles_with_run_report():
    srv = build(ServeConfig(server_factory=fast_sim, replicas=2,
                            target_batch=4, deadline=0.005,
                            policy="block", max_queue=32, trace=True))
    sched = srv.session()
    for r in sim_requests(20, max_new_tokens=4):
        assert sched.submit(r)
    outs = sched.result()
    assert len(outs) == 20
    rep = sched.report()
    trep = sched.trace_report()
    assert trep is trep                       # same shared tracer object
    assert srv.tracer is sched.tracer
    reconcile(rep, trep)
    assert trep.counts["submit"] == 20
    assert trep.counts["admit"] == 20
    assert trep.dominant_stage() in ("queue_wait", "encode",
                                     "device_execute")
    assert "spans" in trep.summary() or trep.summary()


def test_shed_and_reject_counts_reconcile():
    srv = build(ServeConfig(server_factory=fast_sim, target_batch=4,
                            deadline=0.002, policy="reject", max_queue=4,
                            trace=True))
    sched = srv.session()
    for r in sim_requests(32, max_new_tokens=4):
        sched.submit(r)
    sched.result()
    rep, trep = sched.report(), sched.trace_report()
    reconcile(rep, trep)
    assert rep.n_rejected > 0                  # overload actually happened


def test_replay_trace_reconciles_and_covers_stages():
    reqs = sim_requests(16, max_new_tokens=4)
    srv = build(ServeConfig(server_factory=fast_sim, replicas=2,
                            routing="sticky", target_batch=4,
                            deadline=0.01, trace=True))
    with srv:
        outs = srv.serve(reqs, mode="pipelined")
    assert len(outs) == 16
    rep, trep = srv.report(), srv.trace_report()
    # replayed streams have no submit-side stages, but encode/device/
    # dispatch/complete must reconcile
    assert trep.counts["complete"] == rep.n_completed
    assert_stats_match(trep.stages["encode"], rep.breakdown["encode"])
    assert_stats_match(trep.stages["device_execute"],
                       rep.breakdown["device"])
    for r, rs in rep.per_replica.items():
        if rs.n_batches:
            assert trep.per_replica[r].n_batches == rs.n_batches
    stages = {s.stage for s in srv.tracer.spans()}
    assert {"encode", "dispatch", "device_execute", "complete"} <= stages
    assert all(s in LIFECYCLE_STAGES for s in stages)


def test_sync_mode_traces_on_replica_zero():
    srv = build(ServeConfig(server_factory=fast_sim, target_batch=4,
                            deadline=0.01, trace=True))
    srv.serve(sim_requests(8, max_new_tokens=4), mode="sync")
    devs = [s for s in srv.tracer.spans() if s.stage == "device_execute"]
    assert devs and all(s.replica == 0 for s in devs)
    trep = srv.trace_report()
    assert trep.counts["complete"] == 8
    assert_stats_match(trep.stages["device_execute"],
                       srv.report().breakdown["device"])


# ---------------------------------------------------------------------------
# cache + engine-drop events on the timeline
# ---------------------------------------------------------------------------

def test_cache_hit_and_coalesce_traced_live():
    srv = build(ServeConfig(server_factory=fast_sim, target_batch=4,
                            deadline=0.005, policy="block", max_queue=32,
                            cache=True, trace=True))
    reqs = sim_requests(24, max_new_tokens=4, unique_keys=4,
                        repeat_alpha=1.1)
    sched = srv.session()
    for r in reqs:
        sched.submit(r)
    outs = sched.result()
    assert len(outs) == 24
    rep, trep = sched.report(), sched.trace_report()
    assert trep.counts.get("cache_hit", 0) == rep.cache["hits"]
    # the lookup sees a raw miss for leaders AND for requests that then
    # coalesce onto one; RunReport splits those two
    assert trep.counts.get("cache_miss", 0) \
        == rep.cache["misses"] + rep.cache["coalesced"]
    assert trep.counts.get("coalesce", 0) == rep.cache["coalesced"]
    assert trep.counts.get("cache_store", 0) > 0
    # every request still completes exactly once on the trace timeline
    assert trep.counts["complete"] == rep.n_completed == 24
    reconcile(rep, trep)


def test_filtered_drop_and_negative_cache_traced():
    srv = build(ServeConfig(
        server_factory=lambda i: FilteringSim(host_ms_per_batch=0.5,
                                              device_ms_per_batch=1.0),
        target_batch=2, deadline=0.005, policy="block", max_queue=16,
        cache={"negative_ttl": 60.0}, trace=True))
    doomed = np.asarray([7, 1, 2, 3], np.int32)
    good = sim_requests(1, max_new_tokens=2)[0]
    from repro.serve import Request
    srv.submit(Request(rid=100, tokens=doomed.copy(), max_new_tokens=2))
    srv.submit(good)
    srv.result()
    stages = {s.stage for s in srv.tracer.spans()}
    assert "drop" in stages                        # engine filtered rid 100
    drop = [s for s in srv.tracer.spans() if s.stage == "drop"][0]
    assert drop.rid == 100 and drop.meta["reason"] == "filtered"
    # second arrival of the same doomed content: negative hit at submit
    srv.submit(Request(rid=101, tokens=doomed.copy(), max_new_tokens=2))
    srv.result()
    spans = srv.tracer.spans()
    neg = [s for s in spans if s.stage == "negative_drop"]
    assert [s.rid for s in neg] == [101]
    assert any(s.stage == "cache_store" and (s.meta or {}).get("negative")
               for s in spans)
    trep = srv.trace_report()
    assert trep.counts.get("cache_negative_hit", 0) == 1


# ---------------------------------------------------------------------------
# capacity-controller actions land on the same timeline
# ---------------------------------------------------------------------------

class ScriptedActuator:
    """Minimal capacity-protocol actuator for driving ticks by hand."""

    def __init__(self):
        self.state = {"queue_depth": 10, "target_batch": 8,
                      "admission_limit": 16, "n_active": 2,
                      "n_replicas": 2, "replica_depths": (1, 1)}

    def capacity_state(self):
        return dict(self.state)

    def set_target_batch(self, n):
        self.state["target_batch"] = n

    def set_admission_limit(self, n):
        self.state["admission_limit"] = n

    def set_active_replicas(self, n):
        self.state["n_active"] = n
        return n


def test_controller_actions_become_trace_events():
    metrics = MetricsCollector()
    tracer = Tracer()
    ctl = CapacityController(ScriptedActuator(),
                             CapacityConfig(confirm=1, window_s=10.0),
                             metrics=metrics, tracer=tracer,
                             clock=lambda: 0.0)
    ctl.tick(now=0.0)                       # priming snapshot
    # host-saturated window: 9s encode busy, 1s device busy over 10s
    for i in range(20):
        metrics.on_arrival(i, 0.0)
    metrics.on_encode(list(range(20)), 0.0, 9.0)
    metrics.on_device(list(range(20)), 9.0, 10.0, replica=0)
    diag = ctl.tick(now=10.0)
    assert str(diag) == "host_bound"
    assert ctl.actions, "host-bound diagnosis must act"
    marks = [s for s in tracer.spans() if s.stage == "controller"]
    assert len(marks) == len(ctl.actions)
    for mark, act in zip(marks, ctl.actions):
        assert mark.meta["action"] == act.action
        assert mark.meta["diagnosis"] == act.diagnosis
        assert mark.meta["before"] == act.before
        assert mark.meta["after"] == act.after


# ---------------------------------------------------------------------------
# rendering + exporters
# ---------------------------------------------------------------------------

def test_render_timeline_shows_lifecycle():
    srv = build(ServeConfig(server_factory=fast_sim, target_batch=4,
                            deadline=0.005, policy="block", max_queue=32,
                            trace=True))
    sched = srv.session()
    reqs = sim_requests(6, max_new_tokens=2)
    for r in reqs:
        sched.submit(r)
    sched.result()
    line = sched.tracer.timeline(reqs[0].rid)
    assert line.startswith(f"rid {reqs[0].rid}:")
    for stage in ("submit@", "admit@", "queue_wait[", "encode[",
                  "device_execute", "complete"):
        assert stage in line
    assert render_timeline([], 999) == "rid 999: (no spans)"


def test_chrome_export_structure(tmp_path):
    srv = build(ServeConfig(server_factory=fast_sim, replicas=2,
                            target_batch=4, deadline=0.005,
                            policy="block", max_queue=32, trace=True))
    sched = srv.session()
    for r in sim_requests(12, max_new_tokens=2):
        sched.submit(r)
    sched.result()
    path = srv.export_trace(str(tmp_path / "trace.json"))
    with open(path) as f:
        payload = json.load(f)
    evs = payload["traceEvents"]
    assert payload["displayTimeUnit"] == "ms"
    phases = {e["ph"] for e in evs}
    assert {"M", "X", "i", "b", "e"} <= phases
    # process + lane naming metadata
    procs = [e for e in evs if e["ph"] == "M"
             and e["name"] == "process_name"]
    assert procs and procs[0]["args"]["name"] == "repro.serve"
    lanes = {e["args"]["name"] for e in evs if e["ph"] == "M"
             and e["name"] == "thread_name"}
    assert "host-encode" in lanes and any(lane.startswith("replica-")
                                          for lane in lanes)
    # device spans live on per-replica lanes (tid 10+replica)
    dev = [e for e in evs if e.get("name") == "device_execute"]
    assert dev and all(e["tid"] >= 10 and e["ph"] == "X" for e in dev)
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in dev)
    # queue waits are async begin/end pairs keyed by rid
    b = [e for e in evs if e["ph"] == "b"]
    e_ = [e for e in evs if e["ph"] == "e"]
    assert len(b) == len(e_) > 0
    assert {x["id"] for x in b} == {x["id"] for x in e_}
    assert chrome_events([]) == []


def test_jsonl_export_roundtrips(tmp_path):
    srv = build(ServeConfig(server_factory=fast_sim, target_batch=4,
                            deadline=0.01, trace=True))
    srv.serve(sim_requests(8, max_new_tokens=2), mode="pipelined")
    path = srv.export_trace(str(tmp_path / "trace.jsonl"), fmt="jsonl")
    rows = [json.loads(line) for line in open(path)]
    assert len(rows) == len(srv.tracer)
    assert all(r["stage"] in LIFECYCLE_STAGES for r in rows)
    assert all(r["t1"] >= r["t0"] for r in rows)
    with pytest.raises(ValueError, match="fmt"):
        srv.export_trace(str(tmp_path / "x"), fmt="yaml")


# ---------------------------------------------------------------------------
# serve() convenience carries trace/cache configs like any other knob
# ---------------------------------------------------------------------------

def test_serve_convenience_with_trace_and_cache():
    outs, rep = serve(sim_requests(12, max_new_tokens=2, unique_keys=3,
                                   repeat_alpha=1.0),
                      server_factory=fast_sim, target_batch=4,
                      deadline=0.01, cache=True, trace=True)
    assert len(outs) == 12
    assert rep.n_completed == 12
    assert rep.cache["hits"] + rep.cache["misses"] \
        + rep.cache["coalesced"] == 12


# ---------------------------------------------------------------------------
# property test: reconciliation holds across seeded workload shapes
# (hypothesis when available, a deterministic grid otherwise)
# ---------------------------------------------------------------------------

def check_seeded_run_reconciles(n, target_batch, replicas, seed):
    srv = build(ServeConfig(
        server_factory=lambda i: SimServer(host_ms_per_batch=0.2,
                                           device_ms_per_batch=0.4),
        replicas=replicas, target_batch=target_batch, deadline=0.003,
        policy="block", max_queue=64, trace=True))
    sched = srv.session()
    for r in sim_requests(n, max_new_tokens=2, rid_base=seed):
        sched.submit(r)
    outs = sched.result()
    assert len(outs) == n
    rep, trep = sched.report(), sched.trace_report()
    reconcile(rep, trep)
    assert trep.counts["submit"] == n
    assert TraceReport.from_spans(sched.tracer.spans()).counts \
        == trep.counts


@pytest.mark.parametrize("n,target_batch,replicas,seed", [
    (1, 1, 1, 0), (5, 3, 2, 11), (16, 6, 3, 42), (9, 2, 2, 1000),
    (12, 4, 1, 7),
])
def test_trace_reconciles_seeded_grid(n, target_batch, replicas, seed):
    check_seeded_run_reconciles(n, target_batch, replicas, seed)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    pass
else:
    @settings(max_examples=8, deadline=None)
    @given(n=st.integers(min_value=1, max_value=16),
           target_batch=st.integers(min_value=1, max_value=6),
           replicas=st.integers(min_value=1, max_value=3),
           seed=st.integers(min_value=0, max_value=2 ** 16))
    def test_trace_reconciles_for_any_seeded_run(n, target_batch,
                                                 replicas, seed):
        check_seeded_run_reconciles(n, target_batch, replicas, seed)

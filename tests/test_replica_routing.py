"""Sharded multi-replica serving: routing policies, per-replica pipelines,
straggler isolation, mesh-derived replica groups."""
import jax
import numpy as np
import pytest

from repro.ft.failures import DelayInjector
from repro.serve import (AsyncScheduler, EngineGroup, OpenLoopGen,
                         RoutingPolicy, SchedulerConfig, ServeConfig,
                         SimServer, SyntheticWorkload, batch_work, build,
                         sim_requests)


# ---------------------------------------------------------------------------
# bit-identity: N replicas, sticky routing vs single-replica sync baseline
# ---------------------------------------------------------------------------

def test_sticky_n_replica_bit_identical_to_sync_baseline():
    """3 sticky-routed replicas must produce completions bit-identical to
    the single-replica synchronous baseline for the same stream (the
    Server.serve bit-identity guarantee)."""
    srv = build(ServeConfig(model="llama3.2-3b", max_seq=48, replicas=3,
                            routing="sticky", target_batch=4,
                            deadline=0.01))
    workload = SyntheticWorkload(vocab=srv.engine.cfg.vocab, prompt_len=6,
                                 max_new_tokens=3, seed=1)
    reqs = OpenLoopGen(workload, qps=200.0, n=12, seed=7).requests()
    sync = srv.serve(reqs, mode="sync")
    sharded = srv.serve(reqs, mode="pipelined")
    assert len(sync) == len(sharded) == 12
    by_sync = {c.rid: c for c in sync}
    for c in sharded:
        ref = by_sync[c.rid]
        np.testing.assert_array_equal(ref.tokens, c.tokens)
        assert ref.batch_size == c.batch_size
        assert ref.truncated == c.truncated
    # sticky placement is content-addressed: every routing decision says so
    rep = srv.report()
    assert rep.routing.get("sticky", 0) > 0
    assert set(rep.routing) <= {"sticky", "single"}


def test_sticky_routing_is_timing_independent():
    """Sticky assignment depends only on batch content (min rid mod R):
    two identical dispatch sequences land on identical replicas."""
    def placements():
        group = EngineGroup.from_servers(
            [SimServer(host_ms_per_batch=0.0, device_ms_per_batch=0.5)
             for _ in range(3)], routing="sticky")
        run = group.open().start()
        seen = []
        for i in range(9):
            pb = group.prepare_batch(sim_requests(2, rid_base=i * 10))
            seen.append(run.dispatch(pb))
        run.finish()
        return seen

    a, b = placements(), placements()
    assert a == b
    assert a == [(i * 10) % 3 for i in range(9)]


# ---------------------------------------------------------------------------
# least-outstanding-work routing under skewed decode lengths
# ---------------------------------------------------------------------------

def test_least_loaded_balances_skewed_decode_lengths():
    """Alternating heavy (long decode) and light batches: work-aware
    routing must not pile the heavy ones onto one replica — per-replica
    busy time stays balanced even though per-batch cost is 16x skewed."""
    group = EngineGroup.from_servers(
        [SimServer(host_ms_per_batch=0.0, device_ms_per_token=1.0)
         for _ in range(2)], routing="least_loaded")
    from repro.serve import MetricsCollector
    metrics = MetricsCollector()
    run = group.open(metrics=metrics).start()
    # heavy batch = 16 decode steps (~16 ms), light = 1 (~1 ms)
    reqs = sim_requests(24, skew=(16, 1))
    for r in reqs:
        run.dispatch(group.prepare_batch([r]))
    run.finish()
    rep = metrics.report()
    assert set(rep.per_replica) == {0, 1}
    busy = [rep.per_replica[i].busy_s for i in (0, 1)]
    assert min(busy) > 0
    assert max(busy) / min(busy) < 2.0      # work-balanced, not count-based
    assert rep.routing.get("least_loaded", 0) > 0


def test_batch_work_counts_prefill_plus_padded_decode():
    rs = sim_requests(2, prompt_len=8, skew=(16, 2))
    # decode loop runs to the batch max for every row: 2*(8+16)
    assert batch_work(rs) == 2 * (8 + 16)
    assert batch_work([]) == 0


def test_tie_break_round_robin_cycles_replicas():
    """With zero outstanding work everywhere, ties cycle round-robin so
    cold replicas warm evenly."""
    group = EngineGroup.from_servers(
        [SimServer(host_ms_per_batch=0.0, device_ms_per_batch=0.0)
         for _ in range(3)])
    run = group.open()
    picks = [run._route(type("PB", (), {"requests": sim_requests(1)})())
             for _ in range(6)]
    assert [i for i, _ in picks] == [0, 1, 2, 0, 1, 2]
    assert all(reason == "tie_break" for _, reason in picks)


# ---------------------------------------------------------------------------
# straggler isolation: one slow replica must not stall shared admission
# ---------------------------------------------------------------------------

def test_slow_replica_does_not_stall_admission_queue():
    """Replica 0 is made a straggler via repro.ft.failures.DelayInjector.
    Least-outstanding-work routing must route around it: the full stream
    completes, and the healthy replica serves more batches."""
    group = EngineGroup.from_servers(
        [SimServer(host_ms_per_batch=0.0, device_ms_per_batch=1.0)
         for _ in range(2)],
        routing="least_loaded",
        delay=DelayInjector({0: 0.05}))     # +50 ms per batch on replica 0
    sched = AsyncScheduler(group, target_batch=2, deadline=0.001,
                           max_queue=8, policy="block")
    for r in sim_requests(32, max_new_tokens=2):
        sched.submit(r)                     # block policy: would wedge if
                                            # the straggler stalled the path
    outs = sched.result()
    assert len(outs) == 32
    rep = sched.report()
    assert rep.max_queue_depth <= 8
    healthy = rep.per_replica[1].n_batches
    straggler = rep.per_replica[0].n_batches
    assert healthy > straggler
    assert healthy + straggler == len(rep.batch_sizes)


# ---------------------------------------------------------------------------
# per-replica metrics + config plumbing
# ---------------------------------------------------------------------------

def test_per_replica_metrics_and_routing_counters():
    srv = build(ServeConfig(
        replicas=2, target_batch=4, deadline=1.0,
        server_factory=lambda i: SimServer(host_ms_per_batch=0.5,
                                           device_ms_per_batch=2.0)))
    outs = srv.serve(sim_requests(32), mode="pipelined")
    assert len(outs) == 32
    rep = srv.report()
    d = rep.as_dict()
    assert set(d["per_replica"]) == {0, 1}
    n_routed = sum(rep.routing.values())
    n_batches = sum(rep.per_replica[i].n_batches for i in (0, 1))
    assert n_routed == n_batches == len(rep.batch_sizes)
    for stats in rep.per_replica.values():
        assert 0.0 <= stats.idle_fraction <= 1.0
        assert stats.max_pipeline_depth >= 0
        assert stats.max_outstanding_work > 0


def test_scheduler_config_replicas_and_routing_expand_group():
    srv_cfg = SchedulerConfig(replicas=3, routing="sticky")
    assert srv_cfg.routing is RoutingPolicy.STICKY
    sched = AsyncScheduler(
        SimServer(host_ms_per_batch=0.0, device_ms_per_batch=0.0), srv_cfg)
    assert len(sched.group.replicas) == 3
    for r in sim_requests(6):
        sched.submit(r)
    assert len(sched.result()) == 6


def test_routing_policy_validation_lists_values():
    with pytest.raises(ValueError, match="least_loaded"):
        SchedulerConfig(routing="fastest_first")
    with pytest.raises(ValueError, match="least_loaded"):
        EngineGroup.from_servers([SimServer()], routing="bogus")


def test_replica_error_propagates_from_result():
    """A dead replica must surface its error out of result(), not wedge
    the dispatcher on the dead replica's full handoff queue."""
    class ExplodingServer(SimServer):
        def execute_prepared(self, pb, *, device=None):
            raise RuntimeError("boom")

    group = EngineGroup.from_servers([ExplodingServer(), ExplodingServer()])
    sched = AsyncScheduler(group, target_batch=1, deadline=0.001,
                           max_queue=16)
    for r in sim_requests(6):
        sched.submit(r)
    with pytest.raises(RuntimeError):
        sched.result()


# ---------------------------------------------------------------------------
# mesh-derived replica groups
# ---------------------------------------------------------------------------

def test_replica_device_groups_partition_mesh():
    from jax.sharding import Mesh

    from repro.sharding.specs import replica_device_groups
    devs = jax.devices()
    mesh = Mesh(np.asarray(devs).reshape(len(devs), 1), ("data", "model"))
    groups = replica_device_groups(mesh, axis="data")
    assert len(groups) == len(devs)
    assert sorted(d.id for g in groups for d in g) == \
        sorted(d.id for d in devs)
    with pytest.raises(ValueError, match="axis"):
        replica_device_groups(mesh, axis="pod")


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >=2 devices "
                           "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
def test_mesh_replicas_bit_identical_on_two_devices():
    """CI matrix job: one replica per mesh slice, least-loaded routing,
    completions bit-identical to the sync baseline."""
    from jax.sharding import Mesh

    from repro.serve import LMServer
    from repro.configs.base import get_config
    devs = jax.devices()
    mesh = Mesh(np.asarray(devs).reshape(len(devs), 1), ("data", "model"))
    server = LMServer(get_config("llama3.2-3b").reduced(), max_seq=48)
    group = EngineGroup.from_mesh(server, mesh, axis="data")
    assert len(group.replicas) == len(devs)
    workload = SyntheticWorkload(vocab=server.cfg.vocab, prompt_len=6,
                                 max_new_tokens=3, seed=1)
    reqs = OpenLoopGen(workload, qps=200.0, n=10, seed=7).requests()
    groups = server.form_batches(reqs, target_batch=4, deadline=0.01)
    sync = [c for rs in groups for c in server.generate_batch(rs)]
    sharded = group.run_groups(groups)
    by_sync = {c.rid: c for c in sync}
    for c in sharded:
        np.testing.assert_array_equal(by_sync[c.rid].tokens, c.tokens)

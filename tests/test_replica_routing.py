"""Sharded multi-replica serving: routing policies, per-replica pipelines,
straggler isolation, mesh-derived replica groups."""
import jax
import numpy as np
import pytest

from repro.ft.failures import DelayInjector
from repro.serve import (AsyncScheduler, EngineGroup, OpenLoopGen,
                         RoutingPolicy, SchedulerConfig, ServeConfig,
                         SimServer, SyntheticWorkload, batch_work, build,
                         sim_requests)


# ---------------------------------------------------------------------------
# bit-identity: N replicas, sticky routing vs single-replica sync baseline
# ---------------------------------------------------------------------------

def test_sticky_n_replica_bit_identical_to_sync_baseline():
    """3 sticky-routed replicas must produce completions bit-identical to
    the single-replica synchronous baseline for the same stream (the
    Server.serve bit-identity guarantee)."""
    srv = build(ServeConfig(model="llama3.2-3b", max_seq=48, replicas=3,
                            routing="sticky", target_batch=4,
                            deadline=0.01))
    workload = SyntheticWorkload(vocab=srv.engine.cfg.vocab, prompt_len=6,
                                 max_new_tokens=3, seed=1)
    reqs = OpenLoopGen(workload, qps=200.0, n=12, seed=7).requests()
    sync = srv.serve(reqs, mode="sync")
    sharded = srv.serve(reqs, mode="pipelined")
    assert len(sync) == len(sharded) == 12
    by_sync = {c.rid: c for c in sync}
    for c in sharded:
        ref = by_sync[c.rid]
        np.testing.assert_array_equal(ref.tokens, c.tokens)
        assert ref.batch_size == c.batch_size
        assert ref.truncated == c.truncated
    # sticky placement is content-addressed: every routing decision says so
    rep = srv.report()
    assert rep.routing.get("sticky", 0) > 0
    assert set(rep.routing) <= {"sticky", "single"}


def test_sticky_routing_is_timing_independent():
    """Sticky assignment depends only on batch content (min rid mod R):
    two identical dispatch sequences land on identical replicas."""
    def placements():
        group = EngineGroup.from_servers(
            [SimServer(host_ms_per_batch=0.0, device_ms_per_batch=0.5)
             for _ in range(3)], routing="sticky")
        run = group.open().start()
        seen = []
        for i in range(9):
            pb = group.prepare_batch(sim_requests(2, rid_base=i * 10))
            seen.append(run.dispatch(pb))
        run.finish()
        return seen

    a, b = placements(), placements()
    assert a == b
    assert a == [(i * 10) % 3 for i in range(9)]


# ---------------------------------------------------------------------------
# least-outstanding-work routing under skewed decode lengths
# ---------------------------------------------------------------------------

def test_least_loaded_balances_skewed_decode_lengths():
    """Alternating heavy (long decode) and light batches: work-aware
    routing must not pile the heavy ones onto one replica — per-replica
    busy time stays balanced even though per-batch cost is 16x skewed."""
    group = EngineGroup.from_servers(
        [SimServer(host_ms_per_batch=0.0, device_ms_per_token=1.0)
         for _ in range(2)], routing="least_loaded")
    from repro.serve import MetricsCollector
    metrics = MetricsCollector()
    run = group.open(metrics=metrics).start()
    # heavy batch = 16 decode steps (~16 ms), light = 1 (~1 ms)
    reqs = sim_requests(24, skew=(16, 1))
    for r in reqs:
        run.dispatch(group.prepare_batch([r]))
    run.finish()
    rep = metrics.report()
    assert set(rep.per_replica) == {0, 1}
    busy = [rep.per_replica[i].busy_s for i in (0, 1)]
    assert min(busy) > 0
    assert max(busy) / min(busy) < 2.0      # work-balanced, not count-based
    assert rep.routing.get("least_loaded", 0) > 0


def test_batch_work_counts_prefill_plus_padded_decode():
    rs = sim_requests(2, prompt_len=8, skew=(16, 2))
    # decode loop runs to the batch max for every row: 2*(8+16)
    assert batch_work(rs) == 2 * (8 + 16)
    assert batch_work([]) == 0


def test_tie_break_round_robin_cycles_replicas():
    """With zero outstanding work everywhere, ties cycle round-robin so
    cold replicas warm evenly."""
    group = EngineGroup.from_servers(
        [SimServer(host_ms_per_batch=0.0, device_ms_per_batch=0.0)
         for _ in range(3)])
    run = group.open()
    picks = [run._route(type("PB", (), {"requests": sim_requests(1)})())
             for _ in range(6)]
    assert [i for i, _, _ in picks] == [0, 1, 2, 0, 1, 2]
    assert all(reason == "tie_break" for _, reason, _ in picks)
    assert all(owner is None for _, _, owner in picks)


# ---------------------------------------------------------------------------
# straggler isolation: one slow replica must not stall shared admission
# ---------------------------------------------------------------------------

def test_slow_replica_does_not_stall_admission_queue():
    """Replica 0 is made a straggler via repro.ft.failures.DelayInjector.
    Least-outstanding-work routing must route around it: the full stream
    completes, and the healthy replica serves more batches."""
    group = EngineGroup.from_servers(
        [SimServer(host_ms_per_batch=0.0, device_ms_per_batch=1.0)
         for _ in range(2)],
        routing="least_loaded",
        delay=DelayInjector({0: 0.05}))     # +50 ms per batch on replica 0
    sched = AsyncScheduler(group, target_batch=2, deadline=0.001,
                           max_queue=8, policy="block")
    for r in sim_requests(32, max_new_tokens=2):
        sched.submit(r)                     # block policy: would wedge if
                                            # the straggler stalled the path
    outs = sched.result()
    assert len(outs) == 32
    rep = sched.report()
    assert rep.max_queue_depth <= 8
    healthy = rep.per_replica[1].n_batches
    straggler = rep.per_replica[0].n_batches
    assert healthy > straggler
    assert healthy + straggler == len(rep.batch_sizes)


# ---------------------------------------------------------------------------
# per-replica metrics + config plumbing
# ---------------------------------------------------------------------------

def test_per_replica_metrics_and_routing_counters():
    srv = build(ServeConfig(
        replicas=2, target_batch=4, deadline=1.0,
        server_factory=lambda i: SimServer(host_ms_per_batch=0.5,
                                           device_ms_per_batch=2.0)))
    outs = srv.serve(sim_requests(32), mode="pipelined")
    assert len(outs) == 32
    rep = srv.report()
    d = rep.as_dict()
    assert set(d["per_replica"]) == {0, 1}
    n_routed = sum(rep.routing.values())
    n_batches = sum(rep.per_replica[i].n_batches for i in (0, 1))
    assert n_routed == n_batches == len(rep.batch_sizes)
    for stats in rep.per_replica.values():
        assert 0.0 <= stats.idle_fraction <= 1.0
        assert stats.max_pipeline_depth >= 0
        assert stats.max_outstanding_work > 0


def test_scheduler_config_replicas_and_routing_expand_group():
    srv_cfg = SchedulerConfig(replicas=3, routing="sticky")
    assert srv_cfg.routing is RoutingPolicy.STICKY
    sched = AsyncScheduler(
        SimServer(host_ms_per_batch=0.0, device_ms_per_batch=0.0), srv_cfg)
    assert len(sched.group.replicas) == 3
    for r in sim_requests(6):
        sched.submit(r)
    assert len(sched.result()) == 6


def test_routing_policy_validation_lists_values():
    with pytest.raises(ValueError, match="least_loaded"):
        SchedulerConfig(routing="fastest_first")
    with pytest.raises(ValueError, match="least_loaded"):
        EngineGroup.from_servers([SimServer()], routing="bogus")


def test_replica_error_propagates_from_result():
    """A dead replica must surface its error out of result(), not wedge
    the dispatcher on the dead replica's full handoff queue."""
    class ExplodingServer(SimServer):
        def execute_prepared(self, pb, *, device=None):
            raise RuntimeError("boom")

    group = EngineGroup.from_servers([ExplodingServer(), ExplodingServer()])
    sched = AsyncScheduler(group, target_batch=1, deadline=0.001,
                           max_queue=16)
    for r in sim_requests(6):
        sched.submit(r)
    with pytest.raises(RuntimeError):
        sched.result()


# ---------------------------------------------------------------------------
# mesh-derived replica groups
# ---------------------------------------------------------------------------

def test_replica_device_groups_partition_mesh():
    from jax.sharding import Mesh

    from repro.sharding.specs import replica_device_groups
    devs = jax.devices()
    mesh = Mesh(np.asarray(devs).reshape(len(devs), 1), ("data", "model"))
    groups = replica_device_groups(mesh, axis="data")
    assert len(groups) == len(devs)
    assert sorted(d.id for g in groups for d in g) == \
        sorted(d.id for d in devs)
    with pytest.raises(ValueError, match="axis"):
        replica_device_groups(mesh, axis="pod")


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >=2 devices "
                           "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
def test_mesh_replicas_bit_identical_on_two_devices():
    """CI matrix job: one replica per mesh slice, least-loaded routing,
    completions bit-identical to the sync baseline."""
    from jax.sharding import Mesh

    from repro.serve import LMServer
    from repro.configs.base import get_config
    devs = jax.devices()
    mesh = Mesh(np.asarray(devs).reshape(len(devs), 1), ("data", "model"))
    server = LMServer(get_config("llama3.2-3b").reduced(), max_seq=48)
    group = EngineGroup.from_mesh(server, mesh, axis="data")
    assert len(group.replicas) == len(devs)
    workload = SyntheticWorkload(vocab=server.cfg.vocab, prompt_len=6,
                                 max_new_tokens=3, seed=1)
    reqs = OpenLoopGen(workload, qps=200.0, n=10, seed=7).requests()
    groups = server.form_batches(reqs, target_batch=4, deadline=0.01)
    sync = [c for rs in groups for c in server.generate_batch(rs)]
    sharded = group.run_groups(groups)
    by_sync = {c.rid: c for c in sync}
    for c in sharded:
        np.testing.assert_array_equal(by_sync[c.rid].tokens, c.tokens)


# ---------------------------------------------------------------------------
# hit-aware routing: cache-ownership affinity with a straggler guard
# ---------------------------------------------------------------------------

def _fast_servers(n, **kw):
    kw.setdefault("host_ms_per_batch", 0.0)
    kw.setdefault("device_ms_per_batch", 0.0)
    return [SimServer(**kw) for _ in range(n)]


def _owned_cache(reqs, replica, *, ttl=1.0, expire_at=10.0):
    """A cache whose every key is an expired tombstone owned by
    ``replica`` — the state hit_aware routing sees when content must be
    recomputed."""
    from repro.serve import CacheConfig, CachedResult, ResultCache, \
        request_key
    cache = ResultCache(CacheConfig(ttl=ttl))
    ref = SimServer()
    for r in reqs:
        cache.put(request_key(r),
                  CachedResult.of(ref.generate_batch([r])[0],
                                  replica=replica, now=0.0))
        assert cache.get(request_key(r), expire_at) is None
    return cache


def test_hit_aware_without_cache_decision_identical_to_least_loaded():
    """No cache (or an empty one): hit_aware must make exactly the
    decisions least_loaded would, including round-robin tie-break state."""
    from repro.serve import CacheConfig, ResultCache
    ga = EngineGroup.from_servers(_fast_servers(3), routing="hit_aware")
    gb = EngineGroup.from_servers(_fast_servers(3), routing="least_loaded")
    runs = [ga.open(), ga.open(cache=ResultCache(CacheConfig())),
            gb.open()]
    pb = type("PB", (), {"requests": sim_requests(2)})()
    for loads in ([0, 0, 0], [5, 1, 3], [2, 2, 9], [7, 7, 7], [0, 4, 0]):
        picks = []
        for run in runs:
            run._outstanding = list(loads)
            picks.append(run._route(pb))
        assert picks[0] == picks[1] == picks[2]
        assert picks[0][1] in ("least_loaded", "tie_break")
        assert picks[0][2] is None


def test_hit_aware_prefers_owning_replica_for_expired_content():
    """Tombstone affinity: the recompute of TTL-expired content routes to
    the replica that produced the original result."""
    reqs = sim_requests(2, rid_base=0, content_seed=3)
    cache = _owned_cache(reqs, replica=2)
    group = EngineGroup.from_servers(_fast_servers(3), routing="hit_aware")
    run = group.open(cache=cache)
    fresh = sim_requests(2, rid_base=100, content_seed=3)  # same content
    pb = type("PB", (), {"requests": fresh})()
    assert run._route(pb) == (2, "affinity_hit", 2)


def test_hit_aware_spills_on_straggler_ewma_and_rehomes():
    """An owner whose latency EWMA marks it a straggler loses its
    affinity: the batch spills to the least-loaded healthy replica and
    the keys are re-homed there, so the next recompute follows the work."""
    from repro.serve import request_key
    reqs = sim_requests(2, rid_base=0, content_seed=5)
    cache = _owned_cache(reqs, replica=0)
    group = EngineGroup.from_servers(_fast_servers(3), routing="hit_aware",
                                     straggler_factor=2.0)
    run = group.open(cache=cache)
    run._ewma = [0.02, 0.001, 0.001]        # replica 0 is 20x the others
    fresh = sim_requests(2, rid_base=100, content_seed=5)
    pb = type("PB", (), {"requests": fresh})()
    idx, reason, owner = run._route(pb)
    assert reason == "affinity_spill" and owner == 0 and idx != 0
    assert cache.owner_hint(request_key(fresh[0])) == idx
    assert cache.stats()["affinity_rehomes"] == len(fresh)
    # re-homed: the same content now affinity-hits its new replica
    assert run._route(pb) == (idx, "affinity_hit", idx)


def test_hit_aware_spills_on_outstanding_gap():
    """A healthy owner still spills when its outstanding-work gap over
    the least-loaded candidate exceeds spill_threshold (and holds the
    batch when it doesn't)."""
    reqs = sim_requests(2, rid_base=0, content_seed=9)
    fresh = sim_requests(2, rid_base=100, content_seed=9)
    pb = type("PB", (), {"requests": fresh})()
    tight = EngineGroup.from_servers(_fast_servers(3), routing="hit_aware",
                                     spill_threshold=5)
    run = tight.open(cache=_owned_cache(reqs, replica=0))
    run._outstanding = [10, 0, 0]
    idx, reason, owner = run._route(pb)
    assert reason == "affinity_spill" and owner == 0 and idx in (1, 2)
    loose = EngineGroup.from_servers(_fast_servers(3), routing="hit_aware",
                                     spill_threshold=96)
    run2 = loose.open(cache=_owned_cache(reqs, replica=0))
    run2._outstanding = [10, 0, 0]
    assert run2._route(pb) == (0, "affinity_hit", 0)


def test_delay_injector_straggler_shows_in_ewma():
    """The per-replica EWMA fed by worker batch timings must mark a
    DelayInjector-delayed replica as the straggler."""
    group = EngineGroup.from_servers(
        _fast_servers(2, device_ms_per_batch=1.0), routing="hit_aware",
        delay=DelayInjector({0: 0.05}))     # +50 ms per batch on replica 0
    run = group.open().start()
    for i in range(4):
        run.dispatch(group.prepare_batch(sim_requests(2, rid_base=i * 10)))
    run.finish()
    e = run.replica_ewma()
    assert e[0] is not None and e[1] is not None
    assert e[0] > group.straggler_factor * e[1]
    with run._lock:
        assert run._is_straggler_locked(0, 2)
        assert not run._is_straggler_locked(1, 2)


def test_ewma_persists_across_runs_on_the_group():
    """The straggler EWMA lives on the EngineGroup: a slow replica
    identified in one run still repels hit_aware traffic in the next run
    (runs are often shorter than the straggler's first batch)."""
    group = EngineGroup.from_servers(
        _fast_servers(2, device_ms_per_batch=1.0), routing="hit_aware",
        delay=DelayInjector({0: 0.05}))
    run = group.open().start()
    for i in range(4):
        run.dispatch(group.prepare_batch(sim_requests(2, rid_base=i * 10)))
    run.finish()
    run2 = group.open().start()
    e = run2.replica_ewma()             # before run2 executes anything
    assert e[0] is not None and e[0] > group.straggler_factor * e[1]
    with run2._lock:
        assert run2._is_straggler_locked(0, 2)
    run2.finish()


def test_hit_aware_end_to_end_spill_under_delay_injector():
    """Every key starts owned by a DelayInjector-straggled replica 0:
    hit_aware must spill most recomputes to the healthy replica, re-home
    the keys, and still complete the full stream."""
    import numpy as np
    from repro.serve import CachedResult, request_key
    # enough batches that the post-EWMA regime (replica 0 confirmed as a
    # straggler after its first ~51 ms batch) dominates the early
    # gap-guard alternation
    n = 32
    cache_cfg = {"ttl": 5.0}
    srv = build(ServeConfig(
        server_factory=lambda i: SimServer(host_ms_per_batch=0.0,
                                           device_ms_per_batch=1.0),
        replicas=2, routing="hit_aware", spill_threshold=8,
        target_batch=2, deadline=0.01, cache=cache_cfg,
        delay=DelayInjector({0: 0.05})))
    seed_reqs = sim_requests(n, rid_base=0, content_seed=13,
                             arrivals=np.arange(n) * 1e-3)
    ref = SimServer()
    for r in seed_reqs:
        srv.cache.put(request_key(r),
                      CachedResult.of(ref.generate_batch([r])[0],
                                      replica=0, now=0.0))
    # logical arrivals 20s later: every entry is stale (ttl 5), leaving
    # replica-0 tombstones, so all n leaders recompute with affinity
    wave = sim_requests(n, rid_base=100, content_seed=13,
                        arrivals=20.0 + np.arange(n) * 1e-3)
    outs = srv.serve(wave, mode="pipelined")
    assert len(outs) == n
    rep = srv.report()
    assert rep.affinity_hits + rep.affinity_spills \
        == len(rep.batch_sizes)                 # every batch had an owner
    assert rep.affinity_spills > 0              # the straggler lost work
    assert srv.cache.stats()["affinity_rehomes"] > 0
    assert rep.per_replica[1].n_batches > rep.per_replica[0].n_batches


def test_hit_aware_knob_validation():
    with pytest.raises(ValueError, match="spill_threshold"):
        EngineGroup.from_servers([SimServer()], spill_threshold=-1)
    with pytest.raises(ValueError, match="straggler_factor"):
        EngineGroup.from_servers([SimServer()], straggler_factor=0.5)
    with pytest.raises(ValueError, match="ewma_alpha"):
        SchedulerConfig(ewma_alpha=0.0)
    with pytest.raises(ValueError, match="spill_threshold"):
        SchedulerConfig(spill_threshold=-2)


@pytest.mark.parametrize("routing", ["least_loaded", "sticky", "hit_aware"])
def test_every_policy_bit_identical_to_sync_with_warm_recomputes(routing):
    """All three routing policies only move *placement*: two waves of the
    same content (the second recomputed after TTL expiry, at warm-content
    device costs) stay bit-identical per rid to the single-replica sync
    baseline. Warmth changes time, never bits."""
    def factory(i):
        return SimServer(host_ms_per_batch=0.0, device_ms_per_batch=0.5,
                         device_ms_per_token=0.05, warm_factor=0.25)

    def wave(rid_base, t0):
        n = 12
        return sim_requests(n, rid_base=rid_base, content_seed=11,
                            arrivals=t0 + np.arange(n) * 1e-3)

    srv = build(ServeConfig(server_factory=factory, replicas=3,
                            routing=routing, target_batch=4, deadline=0.01,
                            cache={"ttl": 5.0}))
    w1 = srv.serve(wave(0, 0.0), mode="pipelined")
    # 20s of logical time later: every wave-1 entry is stale, so wave 2
    # recomputes through the router (hit_aware sees tombstone owners)
    w2 = srv.serve(wave(100, 20.0), mode="pipelined")
    ref_srv = build(ServeConfig(server_factory=factory, replicas=1,
                                target_batch=4, deadline=0.01))
    ref = {c.rid: c for c in ref_srv.serve(wave(0, 0.0), mode="sync")}
    assert len(w1) == len(w2) == len(ref) == 12
    for c in w1:
        np.testing.assert_array_equal(ref[c.rid].tokens, c.tokens)
        assert ref[c.rid].truncated == c.truncated
    for c in w2:
        np.testing.assert_array_equal(ref[c.rid - 100].tokens, c.tokens)
        assert ref[c.rid - 100].truncated == c.truncated
    if routing == "hit_aware":
        rep = srv.report()
        assert rep.affinity_hits + rep.affinity_spills > 0

"""Tests for the bench-regression CI gate (benchmarks/check_regression.py).

The checker is a script, not a package module, so it is loaded by path.
"""
import importlib.util
import json
import os

import pytest

_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "check_regression.py")


@pytest.fixture(scope="module")
def cr():
    spec = importlib.util.spec_from_file_location("check_regression", _PATH)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _payload(*, replicas_qps=1000.0, cache_qps=2000.0, routing_qps=3000.0,
             capacity_qps=1500.0):
    return {
        "results": [
            {"name": "fig13_replicas_4", "achieved_qps": replicas_qps},
            # machine-dependent points the gate must ignore
            {"name": "fig13_load_1x", "us_per_call": 1e4},
            {"name": "fig13_pipeline_overlap", "sync_s": 1.0},
        ],
        "cache": [{"repeat_alpha": 1.1, "cached": True,
                   "effective_qps": cache_qps}],
        "routing": [{"scenario": "straggler", "policy": "hit_aware",
                     "effective_qps": routing_qps}],
        "capacity": [
            {"profile": "weak_host", "controlled_qps": capacity_qps,
             "best_static_qps": capacity_qps * 1.01},
            {"cost_report": {"rows": []}},   # no profile: must be skipped
        ],
    }


def test_collect_metrics_covers_sections_and_skips_noise(cr):
    m = cr.collect_metrics(_payload())
    assert m["replicas[fig13_replicas_4].achieved_qps"] == 1000.0
    assert m["cache[alpha=1.1,on].effective_qps"] == 2000.0
    assert m["routing[straggler/hit_aware].effective_qps"] == 3000.0
    assert m["capacity[weak_host].controlled_qps"] == 1500.0
    assert not any("fig13_load" in k or "pipeline_overlap" in k for k in m)


def test_within_tolerance_passes(cr):
    base = _payload()
    fresh = _payload(replicas_qps=900.0, routing_qps=2600.0)  # -10%, -13%
    assert cr.compare(base, fresh, 0.15) == []


def test_regression_fails_and_names_the_section(cr):
    base = _payload()
    fresh = _payload(routing_qps=2000.0)     # -33%, well past 15%
    failures = cr.compare(base, fresh, 0.15)
    assert len(failures) == 1
    assert "routing[straggler/hit_aware]" in failures[0]
    assert "REGRESSION" in failures[0]


def test_missing_baseline_metric_fails(cr):
    base = _payload()
    fresh = _payload()
    del fresh["cache"]
    failures = cr.compare(base, fresh, 0.15)
    assert any("MISSING cache[alpha=1.1,on]" in f for f in failures)


def test_new_fresh_metric_is_tolerated(cr):
    base = _payload()
    del base["routing"]           # baseline predates the routing sweep
    fresh = _payload()
    assert cr.compare(base, fresh, 0.15) == []


def test_main_exit_codes(cr, tmp_path):
    base_p = tmp_path / "base.json"
    fresh_p = tmp_path / "fresh.json"
    base_p.write_text(json.dumps(_payload()))
    fresh_p.write_text(json.dumps(_payload()))
    assert cr.main(["--baseline", str(base_p),
                    "--fresh", str(fresh_p)]) == 0
    fresh_p.write_text(json.dumps(_payload(cache_qps=100.0)))
    assert cr.main(["--baseline", str(base_p),
                    "--fresh", str(fresh_p)]) == 1


def test_gate_accepts_the_committed_baseline_against_itself(cr):
    """The committed BENCH_endtoend.json must pass its own gate — the
    exact comparison CI makes when nothing changed."""
    path = os.path.join(os.path.dirname(_PATH), "..",
                        "BENCH_endtoend.json")
    if not os.path.exists(path):
        pytest.skip("no committed baseline")
    with open(path) as f:
        payload = json.load(f)
    assert cr.compare(payload, payload, 0.15) == []
    assert cr.collect_metrics(payload), \
        "committed baseline carries no comparable metrics"

"""Rule model: schema counts, weights, generator statistics."""
import numpy as np

from repro.core.rules import (WILDCARD, Rule, generate_queries,
                              generate_rules, schema_v1, schema_v2)


def test_schema_criteria_counts():
    # paper: 22 consolidated criteria in v1, 26 in v2
    assert len(schema_v1()) == 22
    assert len(schema_v2()) == 26


def test_v2_cross_fields_present():
    s2 = {c.name: c for c in schema_v2()}
    for side in ("arr", "dep"):
        assert s2[f"{side}_op_carrier"].cross_fields is not None
        assert s2[f"{side}_cs_flightno"].cross_fields is not None


def test_rule_weight_monotone_in_bound_criteria():
    schema = schema_v1()
    r_generic = Rule(values={"airport": 5}, decision=30)
    r_precise = Rule(values={"airport": 5, "arr_terminal": 1}, decision=30)
    assert r_precise.weight(schema) > r_generic.weight(schema)


def test_v2_range_weight_penalises_wide_ranges():
    schema = schema_v2()
    narrow = Rule(values={"airport": 1, "arr_flightno": (100, 110)},
                  decision=30)
    wide = Rule(values={"airport": 1, "arr_flightno": (100, 5000)},
                decision=30)
    assert narrow.weight(schema, 2) > wide.weight(schema, 2)
    # v1 has no dynamic penalty
    assert narrow.weight(schema, 1) == wide.weight(schema, 1)


def test_generator_scales_and_skew():
    rs = generate_rules(2_000, version=2, seed=1)
    assert len(rs.rules) == 2_000
    airports = [r.values["airport"] for r in rs.rules]
    # Zipf skew: the most common airport appears far more than median
    counts = np.bincount(airports)
    assert counts.max() > 20 * max(np.median(counts[counts > 0]), 1)


def test_queries_have_all_fields():
    rs = generate_rules(100, version=2, seed=2)
    qs = generate_queries(rs, 50, seed=3)
    keys = set(qs[0])
    for q in qs:
        assert set(q) == keys
    assert "arr_cs" in keys and "dep_cs" in keys

"""Checkpoint store: roundtrip, atomic LATEST, gc, async, resharding hook."""
import json
import os
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import store


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
            "b": {"c": jnp.asarray(rng.integers(0, 9, (3,)), jnp.int32),
                  "d": [jnp.ones((2, 2), jnp.bfloat16),
                        jnp.zeros((5,), jnp.float32)]}}


def test_roundtrip(tmp_path):
    t = _tree()
    store.save(tmp_path, 7, t)
    assert store.latest_step(tmp_path) == 7
    restored = store.restore(tmp_path, 7, jax.eval_shape(lambda: t))
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32)
                                      if a.dtype == jnp.bfloat16
                                      else np.asarray(a),
                                      np.asarray(b, np.float32)
                                      if b.dtype == jnp.bfloat16
                                      else np.asarray(b))


def test_gc_keeps_last_k(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        store.save(tmp_path, s, t, keep=2)
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [4, 5]


def test_latest_pointer_ignores_missing_dir(tmp_path):
    t = _tree()
    store.save(tmp_path, 3, t)
    (tmp_path / "LATEST").write_text("99")
    assert store.latest_step(tmp_path) is None


def test_shape_mismatch_raises(tmp_path):
    store.save(tmp_path, 1, {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        store.restore(tmp_path, 1,
                      {"a": jax.ShapeDtypeStruct((3, 3), jnp.float32)})


def test_async_checkpointer(tmp_path):
    ck = store.AsyncCheckpointer(tmp_path, keep=2)
    t = _tree()
    ck.save(10, t)
    ck.wait()
    assert store.latest_step(tmp_path) == 10
    ck.save(20, t)
    ck.save(30, t)   # waits for 20 first
    ck.wait()
    assert store.latest_step(tmp_path) == 30
    assert 10 not in [int(p.name.split("_")[1])
                      for p in tmp_path.glob("step_*")]

"""Capacity subsystem: bottleneck classification on synthetic signal
streams (including hysteresis / no-flap), deterministic controller policy
against a fake actuator, AIMD convergence, disabled-by-default
bit-identity, end-to-end SimServer convergence for host-bound and
device-bound boxes, and cost-report pricing."""
import time

import numpy as np
import pytest

from repro.capacity import (PAPER_BOXES, Bottleneck, BottleneckMonitor,
                            CapacityConfig, CapacityController,
                            CapacitySignals, CostReport, SignalSnapshot)
from repro.core.cost_model import (aws_accel_usd_per_hour,
                                   aws_host_usd_per_hour,
                                   usd_per_1k_queries)
from repro.serve import (AsyncScheduler, Request, SchedulerConfig,
                         ServeConfig, SimServer, build, sim_requests)


def _sig(**kw):
    """CapacitySignals with quiet defaults; override per test."""
    base = dict(t=0.0, window_s=0.25, arrival_rate=100.0,
                completion_rate=100.0, reject_rate=0.0,
                host_prepare_rate=50.0, host_busy_fraction=0.2,
                device_idle_fraction=0.3, queue_fill=0.2,
                cache_hit_rate=0.0)
    base.update(kw)
    return CapacitySignals(**base)


def _snap(t, **kw):
    base = dict(t=t, n_arrivals=0, n_completions=0, n_rejected=0,
                n_shed=0, n_encoded_batches=0, encode_busy_s=0.0,
                device_busy_s=0.0, cache_hits=0, cache_misses=0,
                cache_coalesced=0)
    base.update(kw)
    return SignalSnapshot(**base)


def _req(rid, tokens, *, max_new=4, arrival=0.0):
    return Request(rid=rid, tokens=np.asarray(tokens, np.int32),
                   max_new_tokens=max_new, arrival=arrival)


# -- monitor: stateless classification ----------------------------------------

def test_classify_idle_stack_is_balanced():
    mon = BottleneckMonitor()
    assert mon.classify(_sig(arrival_rate=0.0, queue_fill=0.0,
                             host_busy_fraction=0.0,
                             device_idle_fraction=1.0)) \
        == Bottleneck.BALANCED


def test_classify_host_bound():
    # the paper's imbalance: host saturated, accelerator starving
    mon = BottleneckMonitor()
    assert mon.classify(_sig(host_busy_fraction=0.95,
                             device_idle_fraction=0.8)) \
        == Bottleneck.HOST_BOUND


def test_classify_device_bound():
    mon = BottleneckMonitor()
    assert mon.classify(_sig(host_busy_fraction=0.2,
                             device_idle_fraction=0.05)) \
        == Bottleneck.DEVICE_BOUND


def test_classify_admission_bound_needs_pressure_and_headroom():
    mon = BottleneckMonitor()
    # queue pinned at the bound, both sides idle: the static limit binds
    assert mon.classify(_sig(queue_fill=0.95, host_busy_fraction=0.2,
                             device_idle_fraction=0.7)) \
        == Bottleneck.ADMISSION_BOUND
    # rejects count as pressure even with a short queue
    assert mon.classify(_sig(queue_fill=0.1, reject_rate=50.0,
                             host_busy_fraction=0.2,
                             device_idle_fraction=0.7)) \
        == Bottleneck.ADMISSION_BOUND
    # same pressure but the device is busy: not an admission problem
    assert mon.classify(_sig(queue_fill=0.95, host_busy_fraction=0.2,
                             device_idle_fraction=0.3)) \
        == Bottleneck.BALANCED


# -- monitor: hysteresis / no-flap --------------------------------------------

def test_one_noisy_window_cannot_flip_the_diagnosis():
    mon = BottleneckMonitor(confirm=2)
    quiet = _sig()
    noisy = _sig(host_busy_fraction=0.95, device_idle_fraction=0.8)
    assert mon.observe(quiet) == Bottleneck.BALANCED
    assert mon.observe(noisy) == Bottleneck.BALANCED      # candidate only
    assert mon.observe(quiet) == Bottleneck.BALANCED      # streak broken
    assert mon.observe(noisy) == Bottleneck.BALANCED      # fresh candidate
    assert mon.history == []                              # never flipped


def test_confirm_consecutive_windows_flip_and_record_history():
    mon = BottleneckMonitor(confirm=2)
    hostish = _sig(t=1.0, host_busy_fraction=0.95,
                   device_idle_fraction=0.8)
    assert mon.observe(hostish) == Bottleneck.BALANCED    # 1st: candidate
    assert mon.observe(hostish) == Bottleneck.HOST_BOUND  # 2nd: confirmed
    assert mon.history == [(1.0, Bottleneck.HOST_BOUND)]
    # staying in the same regime adds no history
    mon.observe(hostish)
    assert len(mon.history) == 1


def test_confirm_one_flips_immediately():
    mon = BottleneckMonitor(confirm=1)
    assert mon.observe(_sig(device_idle_fraction=0.05)) \
        == Bottleneck.DEVICE_BOUND
    assert len(mon.history) == 1


def test_candidate_switch_resets_the_streak():
    mon = BottleneckMonitor(confirm=3)
    host = _sig(host_busy_fraction=0.95, device_idle_fraction=0.8)
    dev = _sig(device_idle_fraction=0.05)
    mon.observe(host)
    mon.observe(host)                 # streak 2 of 3 toward HOST_BOUND
    mon.observe(dev)                  # different candidate: streak resets
    assert mon.diagnosis == Bottleneck.BALANCED
    mon.observe(dev)
    assert mon.diagnosis == Bottleneck.BALANCED
    mon.observe(dev)                  # 3 consecutive DEVICE_BOUND windows
    assert mon.diagnosis == Bottleneck.DEVICE_BOUND


# -- CapacitySignals.between --------------------------------------------------

def test_between_turns_cumulative_snapshots_into_window_rates():
    prev = _snap(1.0, n_arrivals=100, n_completions=90, n_shed=2,
                 n_encoded_batches=10, encode_busy_s=0.5,
                 device_busy_s=0.4, cache_hits=10, cache_misses=80,
                 cache_coalesced=10)
    cur = _snap(1.5, n_arrivals=200, n_completions=160, n_rejected=5,
                n_shed=2, n_encoded_batches=25, encode_busy_s=0.9,
                device_busy_s=0.8, cache_hits=30, cache_misses=150,
                cache_coalesced=20)
    s = CapacitySignals.between(prev, cur, queue_depth=32,
                                admission_limit=64, n_active_replicas=2,
                                replica_queue_depths=(1, 2))
    assert s.window_s == pytest.approx(0.5)
    assert s.arrival_rate == pytest.approx(200.0)
    assert s.completion_rate == pytest.approx(140.0)
    assert s.reject_rate == pytest.approx(10.0)       # 5 rejects + 0 sheds
    assert s.host_prepare_rate == pytest.approx(30.0)
    assert s.host_busy_fraction == pytest.approx(0.8)
    # busy 0.4s over a 0.5s window across 2 active replicas = 0.4 busy
    assert s.device_idle_fraction == pytest.approx(0.6)
    assert s.queue_fill == pytest.approx(0.5)
    # (20 hits + 10 coalesced) / 100 tracked in the window
    assert s.cache_hit_rate == pytest.approx(0.3)
    assert s.replica_queue_depths == (1, 2)


def test_between_is_safe_on_degenerate_windows():
    prev = _snap(1.0)
    s = CapacitySignals.between(prev, _snap(1.0), queue_depth=0,
                                admission_limit=0)
    assert s.cache_hit_rate == 0.0 and s.queue_fill == 0.0
    assert 0.0 <= s.device_idle_fraction <= 1.0


# -- config coercion ----------------------------------------------------------

def test_capacity_config_coerce_spellings():
    assert CapacityConfig.coerce(None) is None
    assert CapacityConfig.coerce(False) is None
    assert isinstance(CapacityConfig.coerce(True), CapacityConfig)
    cfg = CapacityConfig.coerce({"max_batch": 16, "confirm": 3})
    assert cfg.max_batch == 16 and cfg.confirm == 3
    explicit = CapacityConfig(window_s=0.1)
    assert CapacityConfig.coerce(explicit) is explicit
    with pytest.raises(ValueError):
        CapacityConfig.coerce("yes please")


# -- controller policy against a fake actuator (threadless ticks) -------------

class FakeActuator:
    def __init__(self, *, target_batch=4, admission_limit=64, n_active=2,
                 n_replicas=4):
        self.target_batch = target_batch
        self.admission_limit = admission_limit
        self.n_active = n_active
        self.n_replicas = n_replicas
        self.queue_depth = 0

    def capacity_state(self):
        return {"queue_depth": self.queue_depth,
                "target_batch": self.target_batch,
                "admission_limit": self.admission_limit,
                "n_active": self.n_active,
                "n_replicas": self.n_replicas,
                "replica_depths": ()}

    def set_target_batch(self, n):
        self.target_batch = n

    def set_admission_limit(self, n):
        self.admission_limit = n

    def set_active_replicas(self, n):
        self.n_active = n
        return n


class ScriptedMetrics:
    """Feeds the controller a pre-scripted SignalSnapshot stream."""

    def __init__(self, snaps):
        self.snaps = list(snaps)
        self.logged = []

    def snapshot(self, now):
        return self.snaps.pop(0)

    def on_capacity(self, entry):
        self.logged.append(entry)


def _hostbound_snaps(n, *, dt=0.1, congested=False):
    """Cumulative stream whose every window diffs to host-saturated /
    device-starved signals (optionally with the queue pinned full)."""
    return [_snap(i * dt, n_arrivals=i * 100, n_completions=i * 50,
                  n_encoded_batches=i * 10, encode_busy_s=i * dt * 0.95,
                  device_busy_s=i * dt * 0.1)
            for i in range(n)]


def test_controller_primes_then_diagnoses_and_grows_batch():
    act = FakeActuator(target_batch=4, n_active=2)
    met = ScriptedMetrics(_hostbound_snaps(6))
    ctl = CapacityController(act, CapacityConfig(confirm=2, min_batch=2,
                                                 max_batch=16),
                             metrics=met, clock=lambda: 0.0)
    assert ctl.tick(0.0) is None                    # priming tick
    assert ctl.tick(0.1) == Bottleneck.BALANCED     # candidate window 1
    assert ctl.tick(0.2) == Bottleneck.HOST_BOUND   # confirmed
    assert act.target_batch == 8                    # doubled once
    ctl.tick(0.3)
    assert act.target_batch == 16                   # doubled to the max
    assert [a["action"] for a in met.logged] \
        == ["grow_batch", "grow_batch"]
    assert ctl.summary()["diagnosis"] == "host_bound"


def test_host_bound_at_max_batch_parks_an_idle_replica():
    act = FakeActuator(target_batch=16, n_active=3)
    met = ScriptedMetrics(_hostbound_snaps(6))
    ctl = CapacityController(
        act, CapacityConfig(confirm=1, max_batch=16, min_replicas=1),
        metrics=met, clock=lambda: 0.0)
    ctl.tick(0.0)
    ctl.tick(0.1)                                   # diagnose + act
    assert act.n_active == 2
    assert met.logged[-1]["action"] == "park_replica"
    ctl.tick(0.2)
    assert act.n_active == 1
    ctl.tick(0.3)                                   # min_replicas floor
    assert act.n_active == 1


def test_device_bound_activates_replicas_within_budget():
    # summed device busy of 0.3s per 0.1s window: saturates up to three
    # active replicas, so the diagnosis holds while the controller ramps
    snaps = [_snap(i * 0.1, n_arrivals=i * 100, n_completions=i * 90,
                   n_encoded_batches=i * 10, encode_busy_s=i * 0.1 * 0.2,
                   device_busy_s=i * 0.1 * 3.0)
             for i in range(8)]
    act = FakeActuator(n_active=1, n_replicas=4)
    met = ScriptedMetrics(snaps)
    ctl = CapacityController(act, CapacityConfig(confirm=1, max_replicas=3),
                             metrics=met, clock=lambda: 0.0)
    # device_busy normalised per active replica: with 1 active the device
    # looks saturated, so each tick activates one more up to the budget
    for i in range(5):
        ctl.tick(i * 0.1)
    assert act.n_active == 3                        # capped by max_replicas
    assert [a["action"] for a in met.logged] \
        == ["activate_replica", "activate_replica"]


def test_admission_bound_aimd_additive_increase():
    snaps = [_snap(i * 0.1, n_arrivals=i * 100, n_completions=i * 100,
                   n_rejected=i * 10, n_encoded_batches=i * 10,
                   encode_busy_s=i * 0.1 * 0.2,
                   device_busy_s=i * 0.1 * 0.2)    # rejecting with headroom
             for i in range(8)]
    act = FakeActuator(admission_limit=64)
    met = ScriptedMetrics(snaps)
    ctl = CapacityController(
        act, CapacityConfig(confirm=1, queue_ai=8, max_queue=96),
        metrics=met, clock=lambda: 0.0)
    for i in range(6):
        ctl.tick(i * 0.1)
    assert act.admission_limit == 96                # 64 +8 +8 +8, clamped
    assert all(a["action"] == "queue_increase" for a in met.logged)


def test_host_bound_congestion_aimd_multiplicative_decrease():
    act = FakeActuator(target_batch=16, n_active=1, admission_limit=128)
    act.queue_depth = 128                           # queue pinned full
    met = ScriptedMetrics(_hostbound_snaps(8))
    ctl = CapacityController(
        act, CapacityConfig(confirm=1, max_batch=16, min_queue=16,
                            queue_md=0.5),
        metrics=met, clock=lambda: 0.0)
    ctl.tick(0.0)
    limits = []
    for i in range(1, 5):
        ctl.tick(i * 0.1)
        limits.append(act.admission_limit)
    assert limits == [64, 32, 16, 16]               # halves, floors at min
    assert met.logged[-1]["action"] == "queue_decrease"


def test_controller_error_is_recorded_not_raised():
    class ExplodingMetrics:
        def snapshot(self, now):
            raise RuntimeError("metrics gone")

        def on_capacity(self, entry):
            pass

    ctl = CapacityController(FakeActuator(), CapacityConfig(window_s=0.01),
                             metrics=ExplodingMetrics(),
                             clock=time.perf_counter)
    ctl.start()
    for _ in range(200):
        if ctl.error is not None:
            break
        time.sleep(0.005)
    ctl.stop()
    assert isinstance(ctl.error, RuntimeError)


def test_mean_active_replicas_is_time_weighted():
    act = FakeActuator(n_active=4)
    ctl = CapacityController(act, CapacityConfig(), clock=lambda: 0.0)
    ctl._active_log = [(0.0, 4), (1.0, 2)]
    # 4 replicas for 1s, then 2 replicas for 3s -> (4 + 6) / 4
    assert ctl.mean_active_replicas(4.0) == pytest.approx(2.5)


# -- disabled by default: bit-identity ----------------------------------------

def test_capacity_none_is_bit_identical_and_unwired():
    reqs = sim_requests(24, max_new_tokens=4, content_seed=7)
    plain = build(ServeConfig(server_factory=lambda i: SimServer()))
    baseline = {c.rid: c for c in plain.serve(reqs, mode="sync")}

    srv = build(ServeConfig(replicas=2, capacity=None,
                            server_factory=lambda i: SimServer()))
    out = {c.rid: c for c in srv.serve(reqs, mode="pipelined")}
    assert set(out) == set(baseline)
    for rid, c in baseline.items():
        np.testing.assert_array_equal(out[rid].tokens, c.tokens)
        assert out[rid].truncated == c.truncated
    rep = srv.report()
    assert rep.capacity == {}                   # nothing wired, nothing logged
    assert rep.as_dict()["capacity"] == {}

    sched = AsyncScheduler(SimServer(), SchedulerConfig())
    assert sched._controller is None            # default config: no thread
    sched.result()


# -- end-to-end: SimServer convergence ----------------------------------------

def _flood(sched, *, seconds, qps):
    """Open-loop unique-content flood; returns the number offered."""
    gap = 1.0 / qps
    t_end = time.monotonic() + seconds
    i = 0
    while time.monotonic() < t_end:
        sched.submit(_req(i, [2 + i % 97, 3 + (i // 97) % 50, 5]))
        i += 1
        time.sleep(gap)
    return i


def test_controller_converges_on_a_host_bound_box():
    # weak_host profile: serial host prepare saturates long before the
    # devices do (the paper's weak-CPU / strong-FPGA box). The controller
    # must diagnose host_bound and grow the batch target to amortise it.
    sched = AsyncScheduler(
        SimServer.from_profile("weak_host"),
        SchedulerConfig(target_batch=4, deadline=0.005, max_queue=32,
                        policy="shed_oldest", replicas=2,
                        capacity=CapacityConfig(window_s=0.05, confirm=2,
                                                min_batch=4, max_batch=32,
                                                min_queue=8)))
    _flood(sched, seconds=0.9, qps=2000)
    sched.result()
    rep = sched.report()
    assert rep.capacity["diagnosis"] == "host_bound" \
        or any(d == "host_bound" for _, d in rep.capacity["history"])
    assert rep.capacity["final"]["target_batch"] > 4
    assert rep.capacity["n_actions"] > 0
    assert rep.capacity["error"] is None


def test_controller_activates_replicas_on_a_device_bound_box():
    # weak_device profile starting from one active replica: the device
    # saturates, the controller must diagnose device_bound and bring the
    # parked replicas back within the budget.
    sched = AsyncScheduler(
        SimServer.from_profile("weak_device"),
        SchedulerConfig(target_batch=8, deadline=0.005, max_queue=64,
                        policy="shed_oldest", replicas=3,
                        capacity=CapacityConfig(window_s=0.05, confirm=2,
                                                initial_replicas=1,
                                                min_batch=4, max_batch=32)))
    sched.start()
    assert sched.capacity_state()["n_active"] == 1      # parked at start
    _flood(sched, seconds=0.9, qps=1500)
    sched.result()
    rep = sched.report()
    assert rep.capacity["diagnosis"] == "device_bound" \
        or any(d == "device_bound" for _, d in rep.capacity["history"])
    assert rep.capacity["final"]["n_active"] > 1        # replicas activated
    assert 1.0 <= rep.capacity["mean_active_replicas"] <= 3.0
    assert rep.capacity["error"] is None


def test_scheduler_actuator_protocol_round_trips():
    sched = AsyncScheduler(SimServer(), SchedulerConfig(
        target_batch=8, max_queue=64, replicas=2))
    st = sched.capacity_state()
    assert st["target_batch"] == 8 and st["admission_limit"] == 64
    assert st["n_active"] == 2 and st["n_replicas"] == 2
    sched.set_target_batch(16)
    sched.set_admission_limit(32)
    sched.set_active_replicas(1)
    st = sched.capacity_state()
    assert st["target_batch"] == 16 and st["admission_limit"] == 32
    assert st["n_active"] == 1
    sched.result()


# -- cost report --------------------------------------------------------------

def test_cost_report_prices_through_the_paper_constants():
    rep = CostReport()
    row = rep.add("weak/static", host="weak_host", replicas=4,
                  achieved_qps=1000.0)
    expect_usd_h = aws_host_usd_per_hour(8) + 4 * aws_accel_usd_per_hour()
    assert row.usd_per_hour == pytest.approx(expect_usd_h)
    assert row.usd_per_1k == pytest.approx(
        usd_per_1k_queries(expect_usd_h, 1000.0))
    # same throughput on fewer active replicas is strictly cheaper
    cheaper = rep.add("weak/controlled", host="weak_host", replicas=1.5,
                      achieved_qps=1000.0)
    assert cheaper.usd_per_1k < row.usd_per_1k
    assert rep.best() is cheaper
    d = rep.as_dict()
    assert d["best"]["config"] == "weak/controlled"
    assert d["rows"][0]["usd_per_1k_queries"] == pytest.approx(
        row.usd_per_1k)
    # markdown table sorts cheapest first
    lines = rep.table().splitlines()
    assert "weak/controlled" in lines[2]


def test_paper_boxes_weak_host_is_cheaper_per_hour():
    weak, bal = PAPER_BOXES["weak_host"], PAPER_BOXES["balanced"]
    assert weak.usd_per_hour(2) < bal.usd_per_hour(2)
    assert weak.usd_per_hour(0) == pytest.approx(aws_host_usd_per_hour(8))


def test_zero_qps_prices_to_infinity():
    assert usd_per_1k_queries(1.0, 0.0) == float("inf")

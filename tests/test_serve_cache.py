"""Result cache + coalescing: content addressing, bit-identity,
single-flight semantics under backpressure, deterministic TTL/LRU,
shared-cache accounting across replicas, and the hit+miss+coalesced
accounting invariant."""
import threading

import numpy as np
import pytest

from repro.serve import (AsyncScheduler, CacheConfig, CachedResult,
                         Coalescer, NegativeResult, Request, ResultCache,
                         SchedulerConfig, ServeConfig, SimServer, build,
                         request_key, sim_requests)


def _req(rid, tokens, *, max_new=4, arrival=0.0):
    return Request(rid=rid, tokens=np.asarray(tokens, np.int32),
                   max_new_tokens=max_new, arrival=arrival)


def _sim_server_cfg(replicas=1, *, cache=True, sim_kw=None, **kw):
    sim_kw = dict(sim_kw or {})
    return ServeConfig(replicas=replicas,
                       cache=CacheConfig() if cache is True else cache,
                       server_factory=lambda i: SimServer(**sim_kw), **kw)


# -- content addressing -------------------------------------------------------

def test_request_key_ignores_rid_and_arrival():
    a = _req(1, [3, 5, 7], arrival=0.0)
    b = _req(999, [3, 5, 7], arrival=42.0)
    assert request_key(a) == request_key(b)


def test_request_key_depends_on_content():
    base = _req(1, [3, 5, 7], max_new=4)
    assert request_key(base) != request_key(_req(1, [3, 5, 8], max_new=4))
    assert request_key(base) != request_key(_req(1, [3, 5, 7], max_new=5))
    assert request_key(base) != request_key(_req(1, [3, 5], max_new=4))


def test_sim_tokens_are_content_derived():
    # the bit-identity anchor for cache substitution: same content, any
    # rid, same simulated output
    srv = SimServer(host_ms_per_batch=0.0, device_ms_per_batch=0.0)
    a, = srv.generate_batch([_req(1, [3, 5, 7])])
    b, = srv.generate_batch([_req(888, [3, 5, 7])])
    c, = srv.generate_batch([_req(1, [3, 5, 9])])
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert not np.array_equal(a.tokens, c.tokens)


# -- ResultCache unit behavior ------------------------------------------------

def test_ttl_expiry_is_judged_on_callers_clock():
    cache = ResultCache(CacheConfig(ttl=10.0))
    key = "k"
    comp = SimServer(host_ms_per_batch=0, device_ms_per_batch=0) \
        .generate_batch([_req(1, [2, 4])])[0]
    cache.put(key, CachedResult.of(comp, now=0.0))
    assert cache.get(key, 9.9) is not None          # fresh
    cache.put(key, CachedResult.of(comp, now=0.0))  # reset stored_at
    assert cache.get(key, 10.1) is None             # stale, evicted
    assert key not in cache
    s = cache.stats()
    assert s["stale"] == 1 and s["entries"] == 0 and s["bytes_resident"] == 0


def test_lru_eviction_is_deterministic():
    comp = SimServer(host_ms_per_batch=0, device_ms_per_batch=0) \
        .generate_batch([_req(1, [2, 4], max_new=4)])[0]
    entry = lambda: CachedResult.of(comp, now=0.0)  # noqa: E731
    # room for exactly two entries
    cache = ResultCache(CacheConfig(max_bytes=2 * entry().nbytes))
    cache.put("a", entry())
    cache.put("b", entry())
    assert cache.get("a", 0.0) is not None          # touch: b is now LRU
    cache.put("c", entry())                         # evicts b, not a
    assert "a" in cache and "c" in cache and "b" not in cache
    assert cache.stats()["evictions"] == 1
    # same sequence, same outcome: strict LRU has no tie-breaking noise
    cache2 = ResultCache(CacheConfig(max_bytes=2 * entry().nbytes))
    cache2.put("a", entry())
    cache2.put("b", entry())
    cache2.get("a", 0.0)
    cache2.put("c", entry())
    assert sorted(k for k in ("a", "b", "c") if k in cache2) \
        == sorted(k for k in ("a", "b", "c") if k in cache)


def test_oversized_entry_evicts_itself():
    comp = SimServer(host_ms_per_batch=0, device_ms_per_batch=0) \
        .generate_batch([_req(1, list(range(1, 9)), max_new=8)])[0]
    cache = ResultCache(CacheConfig(max_bytes=1))
    cache.put("k", CachedResult.of(comp, now=0.0))
    assert len(cache) == 0 and cache.bytes_resident == 0


# -- cached serve(): bit-identity + determinism -------------------------------

def test_cached_pipelined_bit_identical_to_uncached_sync():
    reqs = sim_requests(24, max_new_tokens=4, unique_keys=6,
                        repeat_alpha=0.8, content_seed=11)
    plain = build(_sim_server_cfg(cache=None))
    baseline = {c.rid: c for c in plain.serve(reqs, mode="sync")}

    cached = build(_sim_server_cfg(replicas=2, routing="sticky"))
    # two waves: second replays the same key population with fresh rids,
    # so it is served almost entirely from the cache
    out1 = {c.rid: c for c in cached.serve(reqs, mode="pipelined")}
    wave2 = sim_requests(24, max_new_tokens=4, rid_base=1000,
                         unique_keys=6, repeat_alpha=0.8, content_seed=11)
    base2 = {c.rid: c for c in plain.serve(wave2, mode="sync")}
    out2 = {c.rid: c for c in cached.serve(wave2, mode="pipelined")}

    assert set(out1) == set(baseline) and set(out2) == set(base2)
    for rid, c in baseline.items():
        np.testing.assert_array_equal(out1[rid].tokens, c.tokens)
        assert out1[rid].truncated == c.truncated
    for rid, c in base2.items():
        np.testing.assert_array_equal(out2[rid].tokens, c.tokens)

    rep = cached.report()
    assert rep.cache["hits"] > 0                    # wave 2 hit the cache
    assert rep.cache["hits"] + rep.cache["misses"] \
        + rep.cache["coalesced"] == 48


def test_cached_bit_identity_on_real_engine():
    reqs = [Request(rid=i, tokens=np.array([2 + i % 3, 5, 9], np.int32),
                    max_new_tokens=2, arrival=0.001 * i)
            for i in range(9)]       # 3 distinct contents, 3x repeated
    plain = build(ServeConfig(model="llama3.2-3b", max_seq=16,
                              target_batch=4, deadline=0.01))
    baseline = {c.rid: c for c in plain.serve(reqs, mode="sync")}
    cached = build(ServeConfig(model="llama3.2-3b", max_seq=16,
                               target_batch=4, deadline=0.01,
                               routing="sticky", cache=True))
    out = {c.rid: c for c in cached.serve(reqs, mode="pipelined")}
    assert set(out) == set(baseline)
    for rid, c in baseline.items():
        np.testing.assert_array_equal(out[rid].tokens, c.tokens)
        assert out[rid].truncated == c.truncated
    rep = cached.report()
    # 3 unique leaders executed; the other 6 coalesced onto them
    assert rep.cache["misses"] == 3
    assert rep.cache["coalesced"] == 6


def test_cache_off_is_unchanged():
    srv = build(_sim_server_cfg(cache=None))
    reqs = sim_requests(16, max_new_tokens=4, unique_keys=4,
                        repeat_alpha=1.0, content_seed=3)
    srv.serve(reqs, mode="pipelined")
    rep = srv.report()
    assert rep.as_dict()["cache"] == {}
    assert srv.cache is None


def test_serve_ttl_uses_logical_arrival_time():
    # TTL is judged against *logical* arrival time, not the microseconds
    # the wall-clock replay actually takes
    srv = build(_sim_server_cfg(cache=CacheConfig(ttl=1.0)))
    srv.serve([_req(0, [3, 3], arrival=0.0)], mode="sync")
    srv.serve([_req(1, [3, 3], arrival=5.0)], mode="sync")   # 5s later
    rep = srv.report()
    assert rep.cache["hits"] == 0
    assert rep.cache["stale"] == 1
    assert rep.cache["misses"] == 2

    # within TTL the revisit is a hit
    srv2 = build(_sim_server_cfg(cache=CacheConfig(ttl=1.0)))
    srv2.serve([_req(0, [3, 3], arrival=0.0)], mode="sync")
    srv2.serve([_req(1, [3, 3], arrival=0.5)], mode="sync")
    assert srv2.report().cache["hits"] == 1

    # same-stream duplicates do not coalesce across a logical gap > TTL:
    # the leader's result would already be stale by then
    srv3 = build(_sim_server_cfg(cache=CacheConfig(ttl=1.0)))
    srv3.serve([_req(0, [3, 3], arrival=0.0),
                _req(1, [3, 3], arrival=5.0),
                _req(2, [3, 3], arrival=5.2)], mode="sync")
    rep3 = srv3.report()
    assert rep3.cache["misses"] == 2          # two leaders (0 and 1)
    assert rep3.cache["coalesced"] == 1       # 2 rides on 1, within TTL


# -- single-flight coalescing under backpressure ------------------------------

def _gated_scheduler(gate, *, cache=None, **cfg_kw):
    """Scheduler over a SimServer whose host prepare blocks on ``gate`` —
    keeps a leader in flight while more submissions arrive."""
    sim = SimServer(host_ms_per_batch=1.0, device_ms_per_batch=0.0,
                    sleep=lambda dt: gate.wait(timeout=5.0))
    cfg = SchedulerConfig(cache=cache if cache is not None
                          else CacheConfig(), **cfg_kw)
    return AsyncScheduler(sim, cfg)


def _wait_for(pred, timeout=5.0):
    import time
    t0 = time.monotonic()
    while not pred():
        if time.monotonic() - t0 > timeout:
            raise AssertionError("condition not reached")
        time.sleep(0.001)


def test_followers_resolve_with_their_leader():
    gate = threading.Event()
    sched = _gated_scheduler(gate, target_batch=1, deadline=0.001,
                             max_queue=8, policy="block")
    got = []
    sched.on_complete = lambda c: got.append(c.rid)
    sched.submit(_req(0, [9, 9]))                   # leader
    _wait_for(lambda: sched.queue_depth == 0)       # batcher holds it
    assert sched.submit(_req(1, [9, 9]))            # follower
    assert sched.submit(_req(2, [9, 9]))            # follower
    assert sched.n_coalesced == 2
    gate.set()
    outs = {c.rid: c for c in sched.result()}
    assert set(outs) == {0, 1, 2}
    for rid in (1, 2):
        np.testing.assert_array_equal(outs[rid].tokens, outs[0].tokens)
    assert sorted(got) == [0, 1, 2]                 # callbacks for all three
    rep = sched.report()
    assert rep.cache["coalesced"] == 2 and rep.cache["misses"] == 1


def test_shed_leader_promotes_its_first_follower():
    # shedding a coalescing leader no longer kills the whole flight: the
    # first follower is promoted to leader (taking a queue slot), so
    # eviction continues to the next-oldest until a slot genuinely frees
    gate = threading.Event()
    sched = _gated_scheduler(gate, target_batch=1, deadline=0.001,
                             max_queue=2, policy="shed_oldest")
    dropped = []
    sched.on_drop = dropped.append
    sched.submit(_req(0, [1, 1]))                   # plug: batcher blocks on
    _wait_for(lambda: sched.queue_depth == 0)       # its host prepare
    sched.submit(_req(1, [9, 9]))                   # leader, queued
    assert sched.submit(_req(2, [9, 9]))            # follower of 1
    sched.submit(_req(3, [5, 5]))                   # queue now full
    sched.submit(_req(4, [6, 6]))                   # sheds leader 1 -> 2
    gate.set()                                      # promoted; then sheds 3
    outs = {c.rid for c in sched.result()}
    assert outs == {0, 2, 4}                        # the flight survived
    assert sorted(dropped) == [1, 3]                # only single requests
    rep = sched.report()
    assert rep.n_shed == 2                          # old leader + next-oldest
    assert rep.cache["leader_promotions"] == 1
    assert rep.cache.get("follower_drops", 0) == 0  # no flight was killed
    # accounting: every accepted submission is a hit, miss, or coalesce
    assert rep.cache["hits"] + rep.cache["misses"] \
        + rep.cache["coalesced"] == sched.n_submitted == 5


def test_promote_on_shed_off_drops_the_flight_atomically():
    # promote_on_shed=False restores the PR 3 semantics: a shed leader
    # takes its followers down with it, in one atomic drop
    gate = threading.Event()
    sched = _gated_scheduler(gate, target_batch=1, deadline=0.001,
                             max_queue=2, policy="shed_oldest",
                             cache=CacheConfig(promote_on_shed=False))
    dropped = []
    sched.on_drop = dropped.append
    sched.submit(_req(0, [1, 1]))                   # plug
    _wait_for(lambda: sched.queue_depth == 0)
    sched.submit(_req(1, [9, 9]))                   # leader, queued
    assert sched.submit(_req(2, [9, 9]))            # follower of 1
    sched.submit(_req(3, [5, 5]))                   # queue now full
    sched.submit(_req(4, [6, 6]))                   # sheds oldest == leader 1
    gate.set()
    outs = {c.rid for c in sched.result()}
    assert outs == {0, 3, 4}                        # leader + follower gone
    assert sorted(dropped) == [1, 2]                # dropped *together*
    rep = sched.report()
    assert rep.n_shed == 1
    assert rep.cache["follower_drops"] == 1
    assert rep.cache.get("leader_promotions", 0) == 0


def test_followers_bypass_a_full_queue():
    gate = threading.Event()
    sched = _gated_scheduler(gate, target_batch=1, deadline=0.001,
                             max_queue=2, policy="reject")
    sched.submit(_req(0, [1, 1]))                   # plug
    _wait_for(lambda: sched.queue_depth == 0)
    sched.submit(_req(1, [9, 9]))                   # leader
    sched.submit(_req(2, [5, 5]))                   # queue full
    assert not sched.submit(_req(3, [6, 6]))        # unique: rejected
    assert sched.submit(_req(4, [9, 9]))            # duplicate: coalesces
    gate.set()
    outs = {c.rid for c in sched.result()}
    assert outs == {0, 1, 2, 4}
    assert sched.n_rejected == 1 and sched.n_coalesced == 1


def test_live_cache_hits_skip_the_pipeline():
    sched = AsyncScheduler(
        SimServer(host_ms_per_batch=0.0, device_ms_per_batch=0.0),
        SchedulerConfig(target_batch=4, deadline=0.001,
                        cache=CacheConfig()))
    for i in range(4):
        sched.submit(_req(i, [7, 7, 7]))
    # drain wave 1 into the cache, then resubmit the same content
    _wait_for(lambda: len(sched.cache) > 0)
    for i in range(4, 8):
        sched.submit(_req(i, [7, 7, 7]))
    outs = {c.rid: c for c in sched.result()}
    assert set(outs) == set(range(8))
    rep = sched.report()
    assert rep.cache["hits"] >= 1                   # wave 2 hit
    assert rep.cache["hits"] + rep.cache["misses"] \
        + rep.cache["coalesced"] == sched.n_submitted == 8
    hit = [outs[i] for i in range(4, 8) if outs[i].prefill_ms == 0.0]
    for c in hit:
        np.testing.assert_array_equal(c.tokens, outs[0].tokens)


# -- negative caching of MCT-filtered verdicts --------------------------------

class FilteringSim(SimServer):
    """SimServer whose execute stage silently drops any request whose
    first token is 13 — the MCT feasibility filter shape: the verdict is
    a property of the *content*, so it is worth negative-caching."""

    def __init__(self, **kw):
        kw.setdefault("host_ms_per_batch", 0.0)
        kw.setdefault("device_ms_per_batch", 0.0)
        super().__init__(**kw)
        self.n_executed = 0

    def execute_prepared(self, pb, *, device=None):
        comps = super().execute_prepared(pb, device=device)
        self.n_executed += len(pb.requests)
        keep = {r.rid for r in pb.requests if int(r.tokens[0]) != 13}
        return [c for c in comps if c.rid in keep]


def test_negative_cache_unit_ttl_and_gating():
    cache = ResultCache(CacheConfig(ttl=100.0, negative_ttl=1.0))
    assert cache.put_negative("k", 0.0)
    assert isinstance(cache.get("k", 0.5), NegativeResult)
    assert cache.get("k", 1.5) is None          # negative TTL expired
    assert "k" not in cache
    s = cache.stats()
    assert s["negative_stores"] == 1 and s["negative_hits"] == 1
    # off by default: put_negative is a no-op unless negative_ttl is set
    off = ResultCache(CacheConfig())
    assert not off.put_negative("k", 0.0)
    assert len(off) == 0


def test_scheduler_negative_hit_skips_execution():
    sim = FilteringSim()
    sched = AsyncScheduler(sim, SchedulerConfig(
        target_batch=1, deadline=0.001,
        cache=CacheConfig(negative_ttl=60.0)))
    dropped = []
    sched.on_drop = dropped.append
    sched.submit(_req(0, [13, 7]))              # executes, gets filtered
    _wait_for(lambda: sched.cache.stats()["negative_stores"] >= 1)
    executed_before = sim.n_executed
    assert sched.submit(_req(1, [13, 7]))       # negative hit: instant drop
    assert sched.submit(_req(2, [5, 5]))        # unrelated content flows
    outs = {c.rid for c in sched.result()}
    assert outs == {2}
    assert sorted(dropped) == [0, 1]
    assert sim.n_executed == executed_before + 1    # rid 1 never ran
    rep = sched.report()
    assert rep.cache["negative_stores"] == 1
    assert rep.cache["negative_hits"] == 1
    assert sched.n_negative_hits == 1
    # extended accounting: negative hits join the invariant
    assert rep.cache["hits"] + rep.cache["misses"] + rep.cache["coalesced"] \
        + rep.cache["negative_hits"] == sched.n_submitted == 3


def test_serve_negative_caching_uses_logical_time():
    srv = build(ServeConfig(cache=CacheConfig(negative_ttl=1.0),
                            server_factory=lambda i: FilteringSim()))
    # first arrival executes and is filtered; the verdict is remembered
    assert srv.serve([_req(0, [13, 4], arrival=0.0)], mode="sync") == []
    # second arrival within TTL: dropped straight from the negative cache
    assert srv.serve([_req(1, [13, 4], arrival=0.5)], mode="sync") == []
    # past TTL the verdict has expired: the content executes (and is
    # filtered, and re-stored) again
    assert srv.serve([_req(2, [13, 4], arrival=2.0)], mode="sync") == []
    rep = srv.report()
    assert rep.cache["negative_stores"] == 2
    assert rep.cache["negative_hits"] == 1
    assert rep.cache["stale"] == 1


def test_followers_of_a_filtered_leader_drop_and_store_once():
    srv = build(ServeConfig(cache=CacheConfig(negative_ttl=10.0),
                            server_factory=lambda i: FilteringSim()))
    out = srv.serve([_req(0, [13, 4], arrival=0.0),
                     _req(1, [13, 4], arrival=0.1)], mode="sync")
    assert out == []
    rep = srv.report()
    assert rep.cache["follower_drops"] == 1
    assert rep.cache["negative_stores"] == 1


# -- shared cache across replicas ---------------------------------------------

def test_shared_cache_hit_accounting_across_replicas():
    srv = build(_sim_server_cfg(replicas=2, routing="sticky",
                                target_batch=4, deadline=1.0))
    wave1 = sim_requests(16, max_new_tokens=4, unique_keys=16,
                         repeat_alpha=0.0, content_seed=21)
    wave2 = sim_requests(16, max_new_tokens=4, rid_base=100,
                         unique_keys=16, repeat_alpha=0.0, content_seed=21)
    srv.serve(wave1, mode="pipelined")
    srv.serve(wave2, mode="pipelined")
    rep = srv.report()
    # wave 2 is an exact content replay: every request hits
    assert rep.cache["hits"] == 16
    # hits are attributed to the replica that produced the cached entry,
    # and the per-replica attribution sums to the global counter
    assert sum(s.cache_hits for s in rep.per_replica.values()) \
        == rep.cache["hits"]
    assert any(s.cache_hits > 0 and 0.0 < s.cache_hit_rate <= 1.0
               for s in rep.per_replica.values())
    # both replicas executed under sticky routing, so both contributed
    assert sum(s.n_requests > 0 for s in rep.per_replica.values()) == 2


# -- accounting invariant (property test) -------------------------------------

def test_accounting_invariant_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=15, deadline=None)
    @given(keys=st.lists(st.integers(0, 4), min_size=1, max_size=30))
    def check(keys):
        sched = AsyncScheduler(
            SimServer(host_ms_per_batch=0.0, device_ms_per_batch=0.0),
            SchedulerConfig(target_batch=4, deadline=0.001,
                            max_queue=64, policy="block",
                            cache=CacheConfig()))
        for i, k in enumerate(keys):
            assert sched.submit(_req(i, [k + 1, k + 1]))
        outs = sched.result()
        rep = sched.report()
        assert rep.cache["hits"] + rep.cache["misses"] \
            + rep.cache["coalesced"] == sched.n_submitted == len(keys)
        assert len(outs) == len(keys)

    check()


# -- context managers / thread reaping ----------------------------------------

def test_scheduler_context_manager_drains_cleanly():
    with AsyncScheduler(
            SimServer(host_ms_per_batch=0.0, device_ms_per_batch=0.0),
            SchedulerConfig(target_batch=2, deadline=0.001)) as sched:
        for i in range(4):
            sched.submit(_req(i, [i + 1, 2]))
    assert not sched._batcher.is_alive()
    assert len(sched.result()) == 4


def test_scheduler_context_manager_reaps_on_exception():
    with pytest.raises(ValueError, match="boom"):
        with AsyncScheduler(
                SimServer(host_ms_per_batch=0.5, device_ms_per_batch=0.5),
                SchedulerConfig(target_batch=2, deadline=0.001)) as sched:
            sched.submit(_req(0, [3, 3]))
            raise ValueError("boom")
    assert not sched._batcher.is_alive()            # no leaked pipeline


def test_server_context_manager_reaps_default_session():
    with pytest.raises(ValueError, match="boom"):
        with build(_sim_server_cfg(cache=None)) as srv:
            srv.submit(_req(0, [3, 3]))
            sched = srv._session
            raise ValueError("boom")
    assert not sched._batcher.is_alive()
    assert srv._session is None                     # close() is idempotent
    srv.close()


def test_run_groups_reaps_workers_when_prepare_raises():
    class ExplodingSim(SimServer):
        def __init__(self):
            super().__init__(host_ms_per_batch=0.0, device_ms_per_batch=0.0)
            self.n_prepared = 0

        def prepare_batch(self, requests):
            self.n_prepared += 1
            if self.n_prepared > 1:
                raise RuntimeError("host encode failed")
            return super().prepare_batch(requests)

    srv = build(ServeConfig(server_factory=lambda i: ExplodingSim(),
                            target_batch=2, deadline=1.0))
    reqs = sim_requests(8, max_new_tokens=2)
    n0 = threading.active_count()
    with pytest.raises(RuntimeError, match="host encode failed"):
        srv.serve(reqs, mode="pipelined")
    _wait_for(lambda: threading.active_count() <= n0)


# -- loadgen repeat mode ------------------------------------------------------

def test_workload_zipf_reuse_bounds_key_population():
    from repro.serve import SyntheticWorkload, zipf_probs
    wl = SyntheticWorkload(prompt_len=6, seed=3, unique_keys=5,
                           repeat_alpha=1.0)
    reqs = wl.build(64)
    keys = {request_key(r) for r in reqs}
    assert 1 <= len(keys) <= 5
    # seeded: same workload, same stream
    keys2 = [request_key(r) for r in SyntheticWorkload(
        prompt_len=6, seed=3, unique_keys=5, repeat_alpha=1.0).build(64)]
    assert keys2 == [request_key(r) for r in reqs]
    # default stays every-request-unique
    uniq = SyntheticWorkload(prompt_len=6, seed=3).build(64)
    assert len({request_key(r) for r in uniq}) == 64
    # zipf weights: normalised, head-heavy for alpha > 0
    p = zipf_probs(5, 1.0)
    assert p[0] > p[-1] and abs(p.sum() - 1.0) < 1e-12
    assert np.allclose(zipf_probs(4, 0.0), 0.25)


def test_sim_requests_content_seed_replays_key_population():
    a = sim_requests(20, unique_keys=4, repeat_alpha=0.5, content_seed=9)
    b = sim_requests(20, rid_base=500, unique_keys=4, repeat_alpha=0.5,
                     content_seed=9)
    assert [request_key(r) for r in a] == [request_key(r) for r in b]
    assert {r.rid for r in a}.isdisjoint({r.rid for r in b})


def test_coalescer_disabled_still_tracks_cache_fill():
    co = Coalescer(enabled=False)
    r = _req(0, [1, 2])
    key = request_key(r)
    assert co.attach(key, _req(1, [1, 2])) is None
    co.claim(key, 0)
    k, followers = co.resolve(0)
    assert k == key and followers == []
    assert co.in_flight() == 0


# -- replica affinity (hit_aware routing support) -----------------------------

def test_ttl_expiry_leaves_affinity_tombstone():
    """A TTL-expired entry forgets its *result* but not its *placement*:
    owner_hint survives as a tombstone so hit_aware routing can send the
    recompute back to the replica that produced it."""
    cache = ResultCache(CacheConfig(ttl=1.0))
    r = _req(1, [3, 5, 7])
    key = request_key(r)
    comp = SimServer().generate_batch([r])[0]
    cache.put(key, CachedResult.of(comp, replica=2, now=0.0))
    assert cache.owner_hint(key) == 2          # live entry's producer
    assert cache.get(key, 10.0) is None        # expired
    assert len(cache) == 0
    assert cache.owner_hint(key) == 2          # tombstone survives
    assert cache.stats()["affinity_entries"] == 1


def test_put_supersedes_affinity_tombstone():
    """A fresh live entry is the authoritative owner record: it clears any
    tombstone so a later expiry can't resurrect a stale owner."""
    cache = ResultCache(CacheConfig(ttl=1.0))
    r = _req(1, [3, 5, 7])
    key = request_key(r)
    comp = SimServer().generate_batch([r])[0]
    cache.put(key, CachedResult.of(comp, replica=0, now=0.0))
    assert cache.get(key, 10.0) is None        # tombstone -> replica 0
    cache.put(key, CachedResult.of(comp, replica=1, now=10.0))
    assert cache.stats()["affinity_entries"] == 0
    assert cache.owner_hint(key) == 1          # live entry wins
    assert cache.get(key, 20.0) is None        # re-expiry tombstones 1
    assert cache.owner_hint(key) == 1


def test_rehome_moves_owner_and_counts():
    cache = ResultCache(CacheConfig(ttl=1.0))
    r = _req(1, [3, 5, 7])
    key = request_key(r)
    comp = SimServer().generate_batch([r])[0]
    cache.put(key, CachedResult.of(comp, replica=0, now=0.0))
    assert cache.get(key, 5.0) is None
    cache.rehome(key, 3)
    assert cache.owner_hint(key) == 3
    assert cache.stats()["affinity_rehomes"] == 1


def test_affinity_map_is_bounded_and_disableable():
    cache = ResultCache(CacheConfig(ttl=1.0, max_affinity=2))
    comp = SimServer().generate_batch([_req(1, [1])])[0]
    keys = []
    for i in range(4):
        r = _req(i, [i, i + 1, i + 2])
        keys.append(request_key(r))
        cache.put(keys[-1], CachedResult.of(comp, replica=i, now=0.0))
        assert cache.get(keys[-1], 5.0) is None     # expire -> tombstone
    assert cache.stats()["affinity_entries"] == 2   # LRU-bounded
    assert cache.owner_hint(keys[0]) is None        # oldest evicted
    assert cache.owner_hint(keys[3]) == 3
    off = ResultCache(CacheConfig(ttl=1.0, max_affinity=0))
    off.put(keys[0], CachedResult.of(comp, replica=1, now=0.0))
    assert off.get(keys[0], 5.0) is None
    assert off.owner_hint(keys[0]) is None          # tombstones disabled


def test_owner_hint_does_not_touch_lru_or_counters():
    """Routing probes must not keep entries artificially fresh or skew
    hit/miss accounting."""
    cache = ResultCache(CacheConfig())
    ra, rb = _req(1, [1, 2, 3]), _req(2, [4, 5, 6])
    ka, kb = request_key(ra), request_key(rb)
    comp = SimServer().generate_batch([ra])[0]
    cache.put(ka, CachedResult.of(comp, replica=0, now=0.0))
    cache.put(kb, CachedResult.of(comp, replica=1, now=0.0))
    before = cache.stats()
    for _ in range(5):
        assert cache.owner_hint(ka) == 0
    after = cache.stats()
    assert after["hits"] == before["hits"]
    assert after["misses"] == before["misses"]
    # ka was probed 5x but kb must still be the most-recently-used entry
    assert next(iter(cache._entries)) == ka

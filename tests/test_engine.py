"""ErbiumEngine: backend agreement, partitioned pruning, CPU baselines,
hot rule reload."""
import numpy as np
import pytest

from repro.core.compiler import compile_rules
from repro.core.encoder import encode_queries
from repro.core.engine import (ErbiumEngine, cpu_match_numpy,
                               cpu_match_python)
from repro.core.rules import generate_queries, generate_rules


@pytest.fixture(scope="module")
def setup():
    rs = generate_rules(600, version=2, seed=11)
    t = compile_rules(rs)
    qs = generate_queries(rs, 256, seed=12)
    enc = encode_queries(t, qs)
    return rs, t, enc


def test_backends_agree(setup):
    rs, t, enc = setup
    pallas = ErbiumEngine(t, tile_b=64, tile_r=128)
    ref = ErbiumEngine(t, backend="ref")
    part = ErbiumEngine(t, tile_r=128, partitioned=True)
    d1, w1, _ = pallas.match(enc)
    d2, w2, _ = ref.match(enc)
    d3, w3, _ = part.match(enc)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d3))
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w3))


def test_cpu_baselines_agree(setup):
    rs, t, enc = setup
    d_np, w_np, _ = cpu_match_numpy(t, enc)
    d_py, w_py, _ = cpu_match_python(t, enc, limit=40)
    np.testing.assert_array_equal(d_np[:40], d_py[:40])
    np.testing.assert_array_equal(w_np[:40], w_py[:40])
    eng = ErbiumEngine(t, backend="ref")
    d_e, w_e, _ = eng.match(enc)
    np.testing.assert_array_equal(np.asarray(d_e), d_np)


def test_hot_reload_changes_rules(setup):
    rs, t, enc = setup
    eng = ErbiumEngine(t, tile_r=128)
    d1, _, _ = eng.match(enc)
    rs2 = generate_rules(600, version=2, seed=99)
    us = eng.reload(rs2)
    assert us > 0 and eng.reload_us == us
    qs2 = generate_queries(rs2, 256, seed=12)
    enc2 = eng.encode(
        __import__("repro.core.encoder", fromlist=["queries_to_arrays"]
                   ).queries_to_arrays(qs2))
    d2, _, _ = eng.match(enc2)
    assert d2.shape == d1.shape


def test_match_rate_with_bias(setup):
    rs, t, enc = setup
    eng = ErbiumEngine(t, backend="ref")
    d, w, rid = eng.match(enc)
    assert float(np.mean(np.asarray(w) >= 0)) > 0.5

"""Chunkwise mLSTM / sLSTM / mamba vs sequential step oracles."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod


@pytest.mark.parametrize("T,chunk", [(16, 4), (17, 8), (32, 32), (7, 16)])
def test_mlstm_chunkwise_matches_sequential(T, chunk):
    rng = np.random.default_rng(T)
    B, D, H = 2, 32, 4
    params = xlstm_mod.init_mlstm(jax.random.PRNGKey(0), D, H, jnp.float32)
    x = jnp.asarray(rng.standard_normal((B, T, D)) * 0.5, jnp.float32)
    out_c = xlstm_mod.mlstm_forward(params, x, n_heads=H, chunk=chunk)
    out_s = xlstm_mod.mlstm_ref(params, x, n_heads=H)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_s),
                               rtol=2e-4, atol=2e-4)


def test_slstm_forward_matches_steps():
    rng = np.random.default_rng(1)
    B, T, D, H = 2, 9, 16, 2
    params = xlstm_mod.init_slstm(jax.random.PRNGKey(1), D, H, jnp.float32)
    x = jnp.asarray(rng.standard_normal((B, T, D)) * 0.5, jnp.float32)
    full = xlstm_mod.slstm_forward(params, x, n_heads=H)
    st = xlstm_mod.slstm_init_state(B, H, D // H)
    outs = []
    for t in range(T):
        y, st = xlstm_mod.slstm_step(params, x[:, t:t + 1], st, n_heads=H)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T,chunk", [(12, 4), (16, 16), (9, 8)])
def test_mamba_chunked_matches_stepwise(T, chunk):
    rng = np.random.default_rng(T + 100)
    B, D = 2, 16
    cfg = SSMConfig(state_dim=8, d_inner_mult=2, conv_width=4, chunk=chunk)
    params = ssm_mod.init_mamba(jax.random.PRNGKey(2), D, cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((B, T, D)) * 0.5, jnp.float32)
    full = ssm_mod.mamba_forward(params, x, cfg=cfg)
    step = ssm_mod.mamba_ref(params, x, cfg=cfg)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("T,chunk", [(12, 4), (16, 16), (9, 8)])
def test_mamba_chunk_local_matches_baseline(T, chunk):
    """The memory-optimised (chunk-local) path is numerically identical."""
    import dataclasses
    rng = np.random.default_rng(T + 200)
    B, D = 2, 16
    cfg = SSMConfig(state_dim=8, d_inner_mult=2, conv_width=4, chunk=chunk)
    cfg_cl = dataclasses.replace(cfg, chunk_local=True)
    params = ssm_mod.init_mamba(jax.random.PRNGKey(4), D, cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((B, T, D)) * 0.5, jnp.float32)
    base = ssm_mod.mamba_forward(params, x, cfg=cfg)
    cl = ssm_mod.mamba_forward(params, x, cfg=cfg_cl)
    np.testing.assert_allclose(np.asarray(cl), np.asarray(base),
                               rtol=2e-5, atol=2e-5)


def test_slstm_local_grad_matches_plain():
    """Custom-VJP (single-psum) sLSTM: values AND grads match the plain
    GSPMD path."""
    B, T, D, H = 2, 9, 16, 2
    params = xlstm_mod.init_slstm(jax.random.PRNGKey(1), D, H, jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((B, T, D)) * 0.5, jnp.float32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    def loss_plain(p, x):
        return jnp.sum(xlstm_mod.slstm_forward(p, x, n_heads=H) ** 2)

    def loss_lg(p, x):
        return jnp.sum(xlstm_mod.slstm_forward_sharded(
            p, x, n_heads=H, mesh=mesh, batch_axes=("data",)) ** 2)

    l1, g1 = jax.value_and_grad(loss_plain)(params, x)
    l2, g2 = jax.value_and_grad(loss_lg)(params, x)
    assert abs(float(l1) - float(l2)) < 1e-5
    for k in g1:
        np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]),
                                   rtol=1e-5, atol=1e-6)


def test_mamba_prefill_state_matches_stepped_state():
    rng = np.random.default_rng(7)
    B, T, D = 1, 11, 8
    cfg = SSMConfig(state_dim=4, d_inner_mult=2, conv_width=4, chunk=4)
    params = ssm_mod.init_mamba(jax.random.PRNGKey(3), D, cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((B, T, D)) * 0.5, jnp.float32)
    st_pre = ssm_mod.mamba_prefill_state(params, x, cfg=cfg)
    st = ssm_mod.mamba_init_state(params, B)
    for t in range(T):
        _, st = ssm_mod.mamba_step(params, x[:, t:t + 1], st, cfg=cfg)
    np.testing.assert_allclose(np.asarray(st_pre.h), np.asarray(st.h),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_pre.conv),
                               np.asarray(st.conv), rtol=2e-4, atol=2e-4)

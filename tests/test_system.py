"""End-to-end behaviour tests: the full paper pipeline (rules -> compiler ->
engine -> workload -> aggregator -> wrapper) and the deployment analyzer."""
import numpy as np
import pytest

from repro.core.aggregator import Batch, batch_stats, greedy_all, paper_policy
from repro.core.compiler import compile_rules
from repro.core.deployment import Config, evaluate, pareto, sweep
from repro.core.encoder import encode_queries
from repro.core.engine import ErbiumEngine, cpu_match_numpy
from repro.core.rules import generate_queries, generate_rules
from repro.core.workload import generate_workload, workload_stats
from repro.core.wrapper import MCTWrapper, StageTimes, measure_stage_times


@pytest.fixture(scope="module")
def system():
    rs = generate_rules(800, version=2, seed=21)
    table = compile_rules(rs)
    eng = ErbiumEngine(table, tile_b=64, tile_r=256)
    wl = generate_workload(rs, 6, seed=2, mean_ts=60.0)
    return rs, table, eng, wl


def test_end_to_end_mct_flow(system):
    rs, table, eng, wl = system
    wrap = MCTWrapper([eng], n_workers=2)
    wrap.start()
    n = 0
    for uq in wl:
        for b in paper_policy(uq):
            wrap.submit(b)
            n += 1
    results = wrap.drain(n)
    wrap.stop()
    assert len(results) == n
    total_q = sum(len(r.decisions) for r in results)
    assert total_q == sum(len(b.queries) for uq in wl
                          for b in paper_policy(uq))
    # decisions agree with the CPU oracle on one batch
    b0 = paper_policy(wl[0])[0]
    enc = encode_queries(table, b0.queries)
    d_cpu, _, _ = cpu_match_numpy(table, enc)
    r0 = [r for r in results if r.uid == wl[0].uid][0]
    np.testing.assert_array_equal(r0.decisions[:len(d_cpu)], d_cpu)


def test_stage_measurement_and_deployment_model(system):
    rs, table, eng, wl = system
    qs = generate_queries(rs, 512, seed=9)

    def make_batch(n):
        return Batch(0, [qs[i % len(qs)] for i in range(n)], [(0, -1)] * n)

    times = measure_stage_times(eng, make_batch, [64, 256, 1024], repeats=2)
    assert all(t.kernel_us > 0 and t.encode_us > 0 for t in times)
    # larger batches cost more in encode (linear-ish)
    assert times[-1].encode_us > times[0].encode_us

    cfgs = [Config(p, w, k, e) for p, w, k, e in
            [(1, 1, 1, 1), (1, 1, 1, 4), (4, 4, 1, 4), (4, 4, 4, 1)]]
    perfs = sweep(cfgs, times, [256, 1024])
    assert all(p.throughput_qps > 0 for p in perfs)
    # more engines reduce single-request latency (Fig 7b)
    lat1 = [p for p in perfs if p.config == cfgs[0] and p.batch == 1024][0]
    lat4 = [p for p in perfs if p.config == cfgs[1] and p.batch == 1024][0]
    assert lat4.latency_us < lat1.latency_us
    front = pareto(perfs)
    assert len(front) >= 1
    for a, b in zip(front, front[1:]):
        assert b.latency_us < a.latency_us


def test_aggregation_improves_batch_sizes(system):
    rs, table, eng, wl = system
    st_paper = batch_stats([b for uq in wl for b in paper_policy(uq)])
    st_greedy = batch_stats([b for uq in wl for b in greedy_all(uq)])
    assert st_greedy["mean"] >= st_paper["mean"]
    assert st_greedy["n_batches"] <= st_paper["n_batches"]

"""HLO static analyzer: cross-validation vs XLA cost_analysis and analytic
FLOP counts; while-loop trip-count multiplication; collective extraction."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_text


def _analyze(fn, *args):
    comp = jax.jit(fn).lower(*args).compile()
    return analyze_text(comp.as_text()), comp


def _xla_cost(comp):
    # jaxlib returns a dict on some versions, a one-element list of dicts
    # (one per computation) on others
    ca = comp.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_matmul_flops_match_xla():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    mine, comp = _analyze(lambda a, b: a @ b, x, w)
    xla = _xla_cost(comp)
    assert abs(mine["flops"] - xla["flops"]) / xla["flops"] < 0.02
    assert abs(mine["flops"] - 2 * 128 * 256 * 512) / mine["flops"] < 0.02


def test_scan_trip_count_multiplied():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, ws)[0]

    mine, comp = _analyze(f, x, ws)
    analytic = 10 * (2 * 64 * 64 * 64)
    assert mine["flops"] >= analytic
    assert mine["flops"] <= analytic * 1.2
    # XLA undercounts by ~trip count
    assert _xla_cost(comp)["flops"] < mine["flops"] / 5


def test_nested_scan_trip_counts():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 4, 32, 32), jnp.float32)

    def f(x, ws):
        def outer(c, wg):
            def inner(ci, w):
                return ci @ w, None
            return jax.lax.scan(inner, c, wg)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    mine, _ = _analyze(f, x, ws)
    analytic = 12 * 2 * 32 * 32 * 32
    assert abs(mine["flops"] - analytic) / analytic < 0.2


def test_elementwise_bytes_reasonable():
    x = jax.ShapeDtypeStruct((1 << 16,), jnp.float32)
    mine, comp = _analyze(lambda a: jnp.exp(a) + 1.0, x)
    # one read + one write at fusion granularity ~ 512 KiB
    assert 2 * 4 * (1 << 16) * 0.5 < mine["bytes"] < 2 * 4 * (1 << 16) * 3


def test_collectives_extracted(monkeypatch):
    import subprocess, sys, textwrap, json
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, json
        from jax.sharding import PartitionSpec as P, NamedSharding
        import sys; sys.path.insert(0, "src")
        from repro.launch.hlo_analysis import analyze_text
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        def f(x, w):
            y = x @ w
            return jax.lax.with_sharding_constraint(
                y, NamedSharding(mesh, P("data", None)))
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        with mesh:
            comp = jax.jit(f, in_shardings=(
                NamedSharding(mesh, P("data", None)),
                NamedSharding(mesh, P(None, "model")))).lower(x, w).compile()
        r = analyze_text(comp.as_text())
        print(json.dumps({"cb": r["collective_bytes"],
                          "wire": r["collective_wire_bytes"],
                          "np": r["num_partitions"]}))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["np"] == 8
    assert sum(res["cb"].values()) > 0
    assert res["wire"] > 0

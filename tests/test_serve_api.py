"""Unified serving front end: ServeConfig/build facade, Server.serve modes,
BackpressurePolicy enum, serve() one-call convenience, removed-shim audit."""
import numpy as np
import pytest

from repro.serve import (BackpressurePolicy, OpenLoopGen, SchedulerConfig,
                         ServeConfig, SimServer, SyntheticWorkload, build,
                         serve, sim_requests)


@pytest.fixture(scope="module")
def srv():
    return build(ServeConfig(model="llama3.2-3b", max_seq=48,
                             target_batch=4, deadline=0.01))


@pytest.fixture(scope="module")
def workload(srv):
    return SyntheticWorkload(vocab=srv.engine.cfg.vocab, prompt_len=6,
                             max_new_tokens=3, seed=1)


def test_build_wires_full_stack(srv):
    assert len(srv.group.replicas) == 1
    assert srv.engine is srv.group.replicas[0].server
    assert srv.engines == [srv.engine]
    assert srv.report().n_requests == 0     # shared collector, fresh


def test_serve_modes_bit_identical(srv, workload):
    """Server.serve documents the bit-identity guarantee: pipelined mode
    must equal the synchronous baseline for the same stream."""
    reqs = OpenLoopGen(workload, qps=200.0, n=12, seed=7).requests()
    sync = srv.serve(reqs, mode="sync")
    pipe = srv.serve(reqs, mode="pipelined")
    assert len(sync) == len(pipe) == 12
    by_sync = {c.rid: c for c in sync}
    for c in pipe:
        np.testing.assert_array_equal(by_sync[c.rid].tokens, c.tokens)
        assert by_sync[c.rid].batch_size == c.batch_size


def test_serve_rejects_unknown_mode(srv, workload):
    with pytest.raises(ValueError, match="mode"):
        srv.serve(workload.build(2), mode="turbo")


def test_default_session_submit_result(srv, workload):
    for r in workload.build(6, rid_base=500):
        assert srv.submit(r)
    outs = srv.result()
    assert sorted(c.rid for c in outs) == list(range(500, 506))
    assert srv.result() == []               # session is drained + recycled
    rep = srv.report()
    assert rep.n_completed >= 6             # shared metrics saw the session


def test_session_overrides_scheduler_knobs(srv, workload):
    sched = srv.session(policy="block", deadline=5.0, max_queue=32,
                        target_batch=2)
    assert sched.cfg.policy is BackpressurePolicy.BLOCK
    for r in workload.build(4, rid_base=600):
        sched.submit(r)
    outs = sched.result()
    assert len(outs) == 4
    assert all(o.batch_size == 2 for o in outs)


# ---------------------------------------------------------------------------
# BackpressurePolicy enum
# ---------------------------------------------------------------------------

def test_policy_enum_accepts_strings_and_members():
    assert SchedulerConfig(policy="reject").policy \
        is BackpressurePolicy.REJECT
    assert SchedulerConfig(policy=BackpressurePolicy.SHED_OLDEST).policy \
        is BackpressurePolicy.SHED_OLDEST
    # str-mixin: existing string comparisons keep working
    assert SchedulerConfig(policy="block").policy == "block"
    assert str(BackpressurePolicy.BLOCK) == "block"


def test_policy_validation_error_lists_valid_values():
    with pytest.raises(ValueError) as ei:
        SchedulerConfig(policy="drop_everything")
    msg = str(ei.value)
    for valid in ("reject", "shed_oldest", "block"):
        assert valid in msg
    assert "drop_everything" in msg


# ---------------------------------------------------------------------------
# the PR-1/PR-2 era shims are gone — the unified surface is the only one
# ---------------------------------------------------------------------------

def test_deprecated_entry_points_removed(srv):
    import repro.serve as S
    assert not hasattr(S, "run_pipelined")
    assert not hasattr(S.scheduler, "run_pipelined")
    assert not hasattr(srv.engine, "serve_stream")


# ---------------------------------------------------------------------------
# serve() one-call convenience
# ---------------------------------------------------------------------------

def test_serve_convenience_returns_completions_and_report():
    outs, rep = serve(
        sim_requests(12), replicas=2, target_batch=4, deadline=1.0,
        server_factory=lambda i: SimServer(device_ms_per_batch=1.0))
    assert len(outs) == 12
    assert rep.n_completed == 12
    assert rep.breakdown["device"].n == 12


def test_serve_convenience_config_xor_kwargs():
    cfg = ServeConfig(server_factory=lambda i: SimServer(), target_batch=4,
                      deadline=1.0)
    outs, rep = serve(sim_requests(4), config=cfg)
    assert len(outs) == 4
    with pytest.raises(ValueError, match="config"):
        serve(sim_requests(2), config=cfg, replicas=2)


def test_build_warmup_knob():
    class WarmSpy(SimServer):
        warmed = None

        def warmup(self, batch_sizes=(1, 8)):
            self.warmed = tuple(batch_sizes)

    srv = build(ServeConfig(server_factory=lambda i: WarmSpy(), replicas=2,
                            warmup=(2, 4)))
    assert all(e.warmed == (2, 4) for e in srv.engines)
    assert build(ServeConfig(server_factory=lambda i: WarmSpy(),
                             warmup=True)).engine.warmed == (1, 8)
    # default stays off; engines without warmup (plain SimServer) tolerate
    # the knob
    assert build(ServeConfig(
        server_factory=lambda i: WarmSpy())).engine.warmed is None
    build(ServeConfig(server_factory=lambda i: SimServer(), warmup=True))


def test_server_facade_works_with_sim_factory():
    srv = build(ServeConfig(
        replicas=2, target_batch=4, deadline=1.0,
        server_factory=lambda i: SimServer(device_ms_per_batch=1.0)))
    assert len(srv.group.replicas) == 2
    assert len(srv.engines) == 2            # distinct engines, one each
    outs = srv.serve(sim_requests(16), mode="pipelined")
    assert len(outs) == 16

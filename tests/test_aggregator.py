"""Batch-formation policies (paper §5) with deterministic logical time."""
import numpy as np
import pytest

from repro.core.aggregator import (DeadlineAggregator, batch_stats,
                                   greedy_all, paper_policy)
from repro.core.rules import generate_rules
from repro.core.workload import (TravelSolution, UserQuery,
                                 generate_workload, workload_stats)


def _uq(uid=0, required=3, pattern=(1, 0, 2, 1, 1)):
    sols = [TravelSolution(c, [{"q": i}] * c if c else [])
            for i, c in enumerate(pattern)]
    return UserQuery(uid=uid, required_ts=required, solutions=sols)


def test_paper_policy_batches_by_required_ts():
    uq = _uq(required=2, pattern=(1, 1, 1, 1))
    batches = paper_policy(uq)
    # 4 indirect TS, required=2 -> 2 batches of 2 TS each
    assert len(batches) == 2
    assert all(len(b.queries) == 2 for b in batches)


def test_paper_policy_skips_direct_flights():
    uq = _uq(required=10, pattern=(0, 0, 3))
    batches = paper_policy(uq)
    assert sum(len(b.queries) for b in batches) == 3


def test_greedy_all_single_batch():
    uq = _uq(required=2, pattern=(1, 2, 1))
    batches = greedy_all(uq)
    assert len(batches) == 1
    assert len(batches[0].queries) == 4


def test_deadline_aggregator_flush_on_target():
    agg = DeadlineAggregator(target_batch=4, deadline=10.0)
    out = agg.offer(0, [{"i": i} for i in range(3)], now=0.0)
    assert out == []
    out = agg.offer(1, [{"i": 3}, {"i": 4}], now=0.1)
    assert len(out) == 1 and len(out[0].queries) == 4
    assert len(agg.flush()[0].queries) == 1


def test_deadline_aggregator_flush_on_deadline():
    agg = DeadlineAggregator(target_batch=100, deadline=1.0)
    agg.offer(0, [{"i": 0}], now=0.0)
    assert agg.poll(now=0.5) == []
    out = agg.poll(now=1.5)
    assert len(out) == 1 and len(out[0].queries) == 1


def test_workload_statistics_match_paper_snapshot():
    rs = generate_rules(100, version=2, seed=0)
    wl = generate_workload(rs, 40, seed=1)
    st = workload_stats(wl)
    # paper snapshot: 17% direct, 1.24 MCT queries per indirect TS
    assert 0.10 <= st["direct_frac"] <= 0.25
    assert 1.05 <= st["mct_per_indirect_ts"] <= 1.45
    assert st["travel_solutions"] > 100 * 40 * 0.5 / 10


def test_batch_stats():
    uq = _uq(required=2, pattern=(1, 1, 1, 1))
    st = batch_stats(paper_policy(uq))
    assert st["n_batches"] == 2 and st["mean"] == 2.0

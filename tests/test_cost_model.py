"""Cost model reproduces the paper's Tables 2-3 totals; TPU balance math."""
import pytest

from repro.core.cost_model import (PAPER_TABLE2_TOTALS, TPUCostParams,
                                   table2, table3, tpu_balance)


def test_table2_reproduces_paper_totals():
    for d in table2():
        expected = PAPER_TABLE2_TOTALS[d.name]
        assert d.total_usd == pytest.approx(expected, rel=0.03), d.name


def test_table2_cloud_ratios():
    rows = {d.name: d for d in table2()}
    aws = rows["AWS / DE + ERBIUM"].total_usd / \
        rows["AWS / Original Domain Explorer"].total_usd
    az = rows["Azure / DE + ERBIUM"].total_usd / \
        rows["Azure / Original Domain Explorer"].total_usd
    # paper: "3x for AWS, and 2.5x for Azure"
    assert 2.8 <= aws <= 3.4
    assert 2.3 <= az <= 2.8


def test_table3_onprem_u50_is_cheapest():
    rows = {d.name: d.total_usd for d in table3()}
    assert rows["On-Premises / DE + ERBIUM + RS (U50)"] < \
        rows["On-Premises / Original DE + Route Scoring"]
    assert rows["On-Premises / DE + ERBIUM + RS (U50)"] < \
        rows["On-Premises / DE + ERBIUM + RS (U200)"]


def test_tpu_balance_imbalance_phenomenon():
    p = TPUCostParams()
    r = tpu_balance(p, target_qps=2e9)
    # host feeding dominates: accelerator under-utilised
    assert r["vcpus_needed"] / (p.host_vcpus_per_8chips / 8) \
        > r["chips_needed"]
    assert r["accel_utilisation"] < 0.2
    # better host:chip ratio fixes it
    p2 = TPUCostParams(host_qps_per_vcpu=2_500_000.0)
    r2 = tpu_balance(p2, target_qps=2e9)
    assert r2["accel_utilisation"] > r["accel_utilisation"] * 5


def test_tpu_balance_monotone_in_load():
    p = TPUCostParams()
    costs = [tpu_balance(p, q)["accel_cost_usd_year"]
             for q in (1e8, 1e9, 1e10)]
    assert costs[0] < costs[1] < costs[2]

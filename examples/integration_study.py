"""Reproduce the paper's integration study end-to-end on this machine:
stage-overhead decomposition (Fig 6), parallel-config sweep (Figs 7-10),
Pareto (Fig 11), CPU-vs-accelerator crossover (Fig 12), and the cost
tables (Tables 2-3) — printed as a single report.

Run:  PYTHONPATH=src python examples/integration_study.py
"""
from benchmarks import (fig4_throughput, fig6_overheads, fig7_10_parallel,
                        fig11_pareto, fig12_cpu_accel, table2_3_cost)


def main():
    print("name,us_per_call,derived")
    print("# --- Fig 4: stand-alone throughput vs batch (v1 vs v2) ---")
    fig4_throughput.run()
    print("# --- Fig 6: stage overhead decomposition ---")
    fig6_overheads.run()
    print("# --- Figs 7-10: parallel configuration series ---")
    fig7_10_parallel.run()
    print("# --- Fig 11: Pareto front ---")
    fig11_pareto.run()
    print("# --- Fig 12: CPU vs accelerator crossover ---")
    fig12_cpu_accel.run()
    print("# --- Tables 2-3: deployment cost ---")
    table2_3_cost.run()


if __name__ == "__main__":
    main()

"""ASYNC SUBMISSION PIPELINE DEMO — the paper's §5–6 imbalance, live.

Sweeps open-loop offered load through the AsyncScheduler and prints the
saturation/imbalance curve: below capacity the device idles (the host
can't form big batches fast enough); past capacity achieved throughput
flattens, queue wait dominates latency, and backpressure rejects.

Also contrasts the synchronous baseline with the double-buffered pipeline
on the same request stream, and a closed-loop run that always fills
target-sized batches.

Run:  PYTHONPATH=src python examples/async_serving.py
"""
import time

from repro.configs.base import get_config
from repro.serve import (AsyncScheduler, ClosedLoopGen, LMServer,
                         OpenLoopGen, SyntheticWorkload)


def main():
    cfg = get_config("llama3.2-3b").reduced()
    server = LMServer(cfg, max_seq=48)
    workload = SyntheticWorkload(vocab=cfg.vocab, prompt_len=6,
                                 max_new_tokens=3, seed=1)

    # capacity: service rate with full batches (pre-compile bucket sizes)
    server.warmup((1, 2, 4, 8))
    warm = workload.build(8, rid_base=10_000)
    t0 = time.perf_counter()
    server.generate_batch(warm)
    cap = 8 / (time.perf_counter() - t0)
    print(f"measured capacity ~{cap:.0f} q/s at batch 8\n")

    print("open-loop sweep (offered load vs achieved / idle / latency):")
    for frac in (0.25, 0.5, 1.0, 2.0, 4.0):
        qps = cap * frac
        # request count must exceed max_queue plus the ~3 batches the
        # pipeline holds in flight, so overload can actually fill the
        # queue and trigger rejections
        sched = AsyncScheduler(server, target_batch=8, deadline=0.01,
                               max_queue=16, policy="reject")
        OpenLoopGen(workload, qps=qps, n=64,
                    seed=int(frac * 100)).drive(sched)
        sched.result()
        rep = sched.report(offered_qps=qps)
        print(f"  {frac:4.2f}x  {rep.summary()}")

    print("\nclosed-loop (concurrency 16, always-full batches):")
    sched = AsyncScheduler(server, target_batch=8, deadline=5.0,
                           max_queue=64, policy="block")
    ClosedLoopGen(workload, concurrency=16, n=32).drive(sched)
    outs = sched.result()
    print(f"  batch sizes: {sorted({o.batch_size for o in outs})}, "
          f"{sched.report().summary()}")

    print("\nsync baseline vs double-buffered pipeline (same stream):")
    reqs = OpenLoopGen(workload, qps=cap, n=24, seed=5).requests()
    t0 = time.perf_counter()
    server.serve_stream(reqs, target_batch=8, deadline=0.01)
    sync_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    server.serve_stream(reqs, target_batch=8, deadline=0.01, pipeline=True)
    pipe_s = time.perf_counter() - t0
    print(f"  sync {sync_s * 1e3:.0f} ms -> pipelined {pipe_s * 1e3:.0f} ms "
          f"({sync_s / pipe_s:.2f}x)")
    print("done.")


if __name__ == "__main__":
    main()

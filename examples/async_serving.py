"""ASYNC SUBMISSION PIPELINE DEMO — the paper's §5–6 imbalance, live.

Built on the unified ``repro.serve`` front end: one ``ServeConfig`` +
``build()`` stands up the engines, replica group, scheduler wiring, and
metrics. Sweeps open-loop offered load through live sessions and prints the
saturation/imbalance curve: below capacity the device idles (the host
can't form big batches fast enough); past capacity achieved throughput
flattens, queue wait dominates latency, and backpressure rejects.

Also contrasts the synchronous baseline with the pipelined path on the
same stream (``Server.serve`` modes — bit-identical outputs), and finishes
with a sharded-serving sweep: simulated engine replicas behind the same
admission path, scaling until the serial host prepare path saturates.

Run:  PYTHONPATH=src python examples/async_serving.py
      PYTHONPATH=src python examples/async_serving.py --smoke   # CI-sized
"""
import time

from repro.serve import (OpenLoopGen, ClosedLoopGen, ServeConfig, SimServer,
                         SyntheticWorkload, build, serve, sim_requests)


def main(smoke: bool = False):
    # --smoke shrinks every sweep to CI size: same code paths, same
    # printed shape, a fraction of the wall time
    fractions = (0.5, 2.0) if smoke else (0.25, 0.5, 1.0, 2.0, 4.0)
    n_open = 32 if smoke else 64
    n_sim_batches = 12 if smoke else 32
    replica_counts = (1, 2) if smoke else (1, 2, 4)
    cfg = ServeConfig(model="llama3.2-3b", max_seq=48,
                      target_batch=8, deadline=0.01,
                      max_queue=16, policy="reject",
                      warmup=(1, 2, 4, 8))      # pre-compile bucket sizes
    srv = build(cfg)
    workload = SyntheticWorkload(vocab=srv.engine.cfg.vocab, prompt_len=6,
                                 max_new_tokens=3, seed=1)

    # capacity: service rate with full batches
    warm = workload.build(8, rid_base=10_000)
    t0 = time.perf_counter()
    srv.engine.generate_batch(warm)
    cap = 8 / (time.perf_counter() - t0)
    print(f"measured capacity ~{cap:.0f} q/s at batch 8\n")

    print("open-loop sweep (offered load vs achieved / idle / latency):")
    for frac in fractions:
        qps = cap * frac
        # request count must exceed max_queue plus the ~3 batches the
        # pipeline holds in flight, so overload can actually fill the
        # queue and trigger rejections
        sched = srv.session()
        OpenLoopGen(workload, qps=qps, n=n_open,
                    seed=int(frac * 100)).drive(sched)
        sched.result()
        rep = sched.report(offered_qps=qps)
        print(f"  {frac:4.2f}x  {rep.summary()}")

    print("\nclosed-loop (concurrency 16, always-full batches):")
    sched = srv.session(policy="block", deadline=5.0, max_queue=64)
    ClosedLoopGen(workload, concurrency=16, n=16 if smoke else 32).drive(sched)
    outs = sched.result()
    print(f"  batch sizes: {sorted({o.batch_size for o in outs})}, "
          f"{sched.report().summary()}")

    print("\nsync baseline vs pipelined (same stream, bit-identical):")
    reqs = OpenLoopGen(workload, qps=cap, n=12 if smoke else 24,
                       seed=5).requests()
    t0 = time.perf_counter()
    srv.serve(reqs, mode="sync")
    sync_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    srv.serve(reqs, mode="pipelined")
    pipe_s = time.perf_counter() - t0
    print(f"  sync {sync_s * 1e3:.0f} ms -> pipelined {pipe_s * 1e3:.0f} ms "
          f"({sync_s / pipe_s:.2f}x)")

    print("\nsharded serving (simulated replicas, shared admission path):")
    sreqs = sim_requests(n_sim_batches * 8, max_new_tokens=4)
    for r in replica_counts:
        # one-call convenience: build -> serve -> teardown -> report
        outs, rep = serve(
            sreqs, replicas=r, target_batch=8, deadline=1.0,
            server_factory=lambda i: SimServer(host_ms_per_batch=3.0,
                                               device_ms_per_batch=8.0))
        print(f"  {r} replica(s): {rep.achieved_qps:6.0f} q/s  "
              f"(host-serial cap {1e3 / 3.0 * 8:.0f} q/s)")

    print("\ntraced run (where did the time go?):")
    tsrv = build(ServeConfig(
        replicas=2, target_batch=8, deadline=1.0, trace=True,
        server_factory=lambda i: SimServer(host_ms_per_batch=3.0,
                                           device_ms_per_batch=8.0)))
    with tsrv:
        touts = tsrv.serve(sreqs[:64], mode="pipelined")
    print(f"  {tsrv.trace_report().summary()}")
    print(f"  {tsrv.tracer.timeline(touts[0].rid)}")
    # tsrv.export_trace("trace.json") -> load in chrome://tracing
    print("done.")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: same code paths, smaller sweeps")
    main(smoke=ap.parse_args().smoke)

"""Quickstart: the ERBIUM-on-TPU rule engine in five steps + a tiny LM.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (ErbiumEngine, compile_rules, generate_queries,
                        generate_rules)
from repro.core.encoder import queries_to_arrays


def main():
    # 1. offline: rules -> compiled dense interval table (the "NFA")
    ruleset = generate_rules(2_000, version=2, seed=0)
    table = compile_rules(ruleset)
    print(f"compiled {table.n_rules} rules x {table.n_cols} criteria "
          f"({table.memory_bytes() / 1e6:.1f} MB table, "
          f"{table.n_partitions} airport partitions)")

    # 2. online: the engine (Pallas kernel in interpret mode on CPU)
    engine = ErbiumEngine(table, n_engines=2, tile_b=256, tile_r=512)

    # 3. queries from the Domain-Explorer side
    queries = generate_queries(ruleset, 1_000, seed=1)
    decisions, weights, rule_ids = engine.match_queries(queries)
    hit = np.mean(np.asarray(weights) >= 0)
    print(f"matched {hit:.0%} of {len(queries)} MCT queries; "
          f"median MCT = {np.median(np.asarray(decisions)[np.asarray(decisions) >= 0]):.0f} min")

    # 4. hot rule update (the paper's 500 us NFA reload)
    us = engine.reload(generate_rules(2_000, version=2, seed=99))
    print(f"rule hot-reload (device table swap): {us:.0f} us")

    # 5. the LM side of the framework: one of the 10 assigned archs, reduced
    import jax
    from repro.configs.base import get_config
    from repro.models.registry import build_model, make_inputs
    cfg = get_config("gemma3-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_inputs(cfg, 2, 32, rng=np.random.default_rng(0))
    print(f"gemma3-1b (reduced) loss = {float(model.loss(params, batch)):.3f}")


if __name__ == "__main__":
    main()

"""END-TO-END DRIVER — a miniature flight-search serving stack, the paper's
architecture on one box:

  Injector (replayed workload)
    -> Domain Explorer (user query -> Travel Solutions -> MCT queries)
    -> DeadlineAggregator (batch formation, the paper's §5 lesson)
    -> MCT Wrapper (workers) -> ERBIUM rule engine   [connection filtering]
    -> LM route scorer (assigned arch, reduced)      [Fig 14 co-location]

Run:  PYTHONPATH=src python examples/serve_search_engine.py
"""
import time

import numpy as np

from repro.core.aggregator import batch_stats, paper_policy
from repro.core.compiler import compile_rules
from repro.core.engine import ErbiumEngine
from repro.core.rules import generate_rules
from repro.core.workload import generate_workload, workload_stats
from repro.core.wrapper import MCTWrapper
from repro.serve import Request, serve


def main():
    # offline: rules + engine
    ruleset = generate_rules(2_000, version=2, seed=0)
    table = compile_rules(ruleset)
    engine = ErbiumEngine(table, tile_b=256, tile_r=512)

    # injector: replay a production-shaped trace
    wl = generate_workload(ruleset, 8, seed=3, mean_ts=120.0)
    print("workload:", workload_stats(wl))

    # MCT stage: wrapper with 2 workers, paper batching policy
    wrap = MCTWrapper([engine], n_workers=2)
    wrap.start()
    t0 = time.perf_counter()
    n_batches = 0
    batches_per_uq = {}
    for uq in wl:
        bs = paper_policy(uq)
        batches_per_uq[uq.uid] = bs
        for b in bs:
            wrap.submit(b)
            n_batches += 1
    results = wrap.drain(n_batches)
    wrap.stop()
    mct_s = time.perf_counter() - t0
    total_q = sum(len(r.decisions) for r in results)
    print(f"MCT stage: {total_q} queries in {n_batches} batches "
          f"({batch_stats([b for bs in batches_per_uq.values() for b in bs])})"
          f" -> {total_q / mct_s:.0f} q/s end-to-end")

    # route scoring stage: LM server scores surviving routes behind the
    # unified repro.serve front end — host encode of batch N+1 overlapped
    # with device execution of batch N (see examples/async_serving.py for
    # the full offered-load and replica sweeps)
    from repro.configs.base import get_config
    vocab = get_config("llama3.2-3b").reduced().vocab
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    tokens=rng.integers(1, vocab, 8).astype(np.int32),
                    max_new_tokens=4, arrival=i * 0.002)
            for i in range(12)]
    outs, rep = serve(reqs, model="llama3.2-3b", max_seq=64,
                      target_batch=4, deadline=0.01, warmup=(4,))
    sizes = [o.batch_size for o in outs]
    print(f"route scoring: {len(outs)} requests served, batch sizes {sizes}")
    print(f"  prefill {np.mean([o.prefill_ms for o in outs]):.1f} ms, "
          f"decode {np.mean([o.decode_ms for o in outs]):.1f} ms (batched)")
    print(f"  {rep.summary()}")
    print("done.")


if __name__ == "__main__":
    main()

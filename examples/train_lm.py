"""Train an LM from the assigned-architecture zoo on the synthetic pipeline
with checkpointing + (optional) injected failure + elastic restart.

Default is a CPU-sized model; pass --width/--layers to scale toward ~100M
(the full-scale path is exercised abstractly by the multi-pod dry-run).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses

from repro.configs.base import get_config
from repro.ft.failures import FailureInjector
from repro.train.loop import TrainConfig, fit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--inject-failure-at", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    cfg = dataclasses.replace(cfg, d_model=args.width,
                              n_layers=args.layers,
                              d_ff=args.width * 4 if cfg.d_ff else 0)
    n = cfg.n_params()
    print(f"{args.arch} (reduced to {n / 1e6:.1f}M params), "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    inj = None
    if args.inject_failure_at:
        inj = FailureInjector({args.inject_failure_at: "host0"})
    tc = TrainConfig(steps=args.steps, batch=args.batch, seq_len=args.seq,
                     lr=1e-3, warmup=20, microbatches=args.microbatches,
                     ckpt_dir=args.ckpt, ckpt_every=50, log_every=10)
    res = fit(cfg, tc, injector=inj)
    print(f"done: {res.steps_done} steps, {res.restarts} restarts, "
          f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}, "
          f"median step {sorted(res.step_times)[len(res.step_times)//2]*1e3:.0f} ms")


if __name__ == "__main__":
    main()
